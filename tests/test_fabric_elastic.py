"""Elastic-fabric property/stress tier: random interleavings of
put/get/add_host/remove_host never lose or duplicate a key, a join
remaps only ~1/N of resident keys (measured, not assumed), topology-mode
NIC service degrades monotonically with fan-in (incast), p99-sized
prefetch leads never regress the seeded schedules vs the fixed lead,
locality routing turns remote restores into local reads, stats reset is
explicit, and the fleet benchmark (churn schedule included) is
byte-identical across in-process runs."""
import importlib.util
import json
import pathlib
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.policy import Tier, TieringPolicy
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import NIC, ShardedTieredStore
from repro.runtime.service import FabricTopology, NetQueueModel
from repro.runtime.tiers import TierSpec, TieredStore
from repro.serving.bench import (compare_churn, multi_host_session_bench,
                                 multi_turn_session_bench)


def _pinned(_h=0):
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def _fabric(n_hosts, **kw):
    return ShardedTieredStore(n_hosts, policy_factory=_pinned,
                              clock=VirtualClock(), **kw)


# ---------------------------------------------------------------------------
# elastic ring: stateful property over op interleavings
# ---------------------------------------------------------------------------

MAX_HOSTS = 7


def _apply_ops(ops, replicas=1):
    """Drive a fabric through an op interleaving while mirroring a plain
    dict model; returns (fabric, model). Op codes: 0/1 put, 2 get,
    3 add_host, 4 remove_host, 5 delete."""
    fab = _fabric(2)
    model = {}
    for code, arg in ops:
        if code in (0, 1):
            key = ("k", arg % 24)
            val = np.full(64, arg, np.int32)
            fab.put(key, val, tier=Tier.FLASH,
                    from_host=fab.host_ids[arg % fab.n_hosts],
                    replicas=replicas)
            model[key] = val
        elif code == 2 and model:
            key = list(model)[arg % len(model)]
            got = fab.get(key, from_host=fab.host_ids[arg % fab.n_hosts])
            np.testing.assert_array_equal(got, model[key])
        elif code == 3 and fab.n_hosts < MAX_HOSTS:
            fab.add_host()
        elif code == 4 and fab.n_hosts > 1:
            fab.remove_host(fab.host_ids[arg % fab.n_hosts])
        elif code == 5 and model:
            key = list(model)[arg % len(model)]
            fab.delete(key)
            del model[key]
    return fab, model


def _check_invariants(fab, model, replicas=1):
    for key, val in model.items():
        holders = fab.holders(key)
        want = min(max(1, replicas), fab.n_hosts)
        assert len(holders) == want, \
            f"{key}: {len(holders)} copies, want {want}"
        assert holders == fab.ring_hosts(key)[:want]   # on ring owners
        for h in holders:                              # never duplicated
            assert fab.hosts[h].tier_of(key) is not None
        got = fab.get(key, from_host=fab.host_ids[0])
        np.testing.assert_array_equal(got, val)
    # no phantom keys survive on any host
    live = {k for s in fab.hosts.values() for k in s.keys()}
    assert live == set(model)


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=5),
                          st.integers(min_value=0, max_value=1000)),
                min_size=1, max_size=24))
def test_elastic_ring_never_loses_or_duplicates_keys(ops):
    fab, model = _apply_ops(ops)
    _check_invariants(fab, model)
    fab.drain()
    _check_invariants(fab, model)


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=5),
                          st.integers(min_value=0, max_value=1000)),
                min_size=1, max_size=16))
def test_elastic_ring_preserves_replication_degree(ops):
    fab, model = _apply_ops(ops, replicas=2)
    _check_invariants(fab, model, replicas=2)


def test_join_remaps_at_most_one_nth_plus_slack():
    """The consistent-hash promise, measured: a 4->5 join moves ~1/5 of
    resident keys/bytes (vnodes bound the imbalance)."""
    fab = _fabric(4)
    blob = np.zeros(1 << 10, np.uint8)
    for i in range(1000):
        fab.put(("k", i), blob, tier=Tier.FLASH, from_host=i % 4)
    fab.drain()
    before = {i: fab.owner(("k", i)) for i in range(1000)}
    rb = fab.add_host()
    assert rb.action == "join" and rb.keys_resident == 1000
    assert 0 < rb.keys_moved, "a join must take over some keys"
    # expected 0.20; generous slack for hash variance, still way below
    # the ~0.8 a naive mod-N reshard would move
    assert rb.moved_fraction <= 1 / 5 + 0.10
    assert rb.bytes_moved == rb.keys_moved * blob.nbytes
    # only keys whose owner changed moved, all to the new host
    moved = {i for i in range(1000) if fab.owner(("k", i)) != before[i]}
    assert len(moved) == rb.keys_moved
    assert all(fab.owner(("k", i)) == rb.host for i in moved)
    fab.drain()
    for i in range(1000):
        assert len(fab.holders(("k", i))) == 1


def test_leave_streams_unique_keys_before_retiring():
    fab = _fabric(3)
    vals = {}
    for i in range(120):
        v = np.full(32, i, np.int32)
        fab.put(("k", i), v, tier=Tier.FLASH, from_host=i % 3)
        vals[("k", i)] = v
    fab.drain()
    victim = fab.host_ids[-1]
    solely = [k for k in vals if fab.holders(k) == [victim]]
    assert solely, "victim must uniquely hold some keys"
    rb = fab.remove_host(victim)
    assert rb.action == "leave"
    assert rb.keys_moved == len(solely)
    assert victim not in fab.host_ids and victim not in fab.hosts
    fab.drain()
    for k, v in vals.items():
        assert victim not in fab.holders(k)
        np.testing.assert_array_equal(fab.get(k, from_host=fab.host_ids[0]),
                                      v)


def test_remote_fetch_survives_owner_departure():
    """A remote fetch in flight when its owner host leaves the fleet
    still resolves: the retired host's NIC lane lives on until the
    egress drains."""
    fab = _fabric(3)
    key = ("kv", "s0")
    owner = fab.owner(key)
    other = next(h for h in fab.host_ids if h != owner)
    fab.put(key, np.full(256, 7, np.int32), tier=Tier.FLASH,
            from_host=owner)
    fab.drain()
    rf = fab.get_async(key, from_host=other)
    fab.remove_host(owner)                      # owner leaves mid-flight
    np.testing.assert_array_equal(rf.wait(), np.full(256, 7, np.int32))
    fab.drain()
    assert owner in fab.retired
    assert fab.holders(key)                     # key re-homed by leave


def test_remove_host_guards():
    fab = _fabric(2)
    with pytest.raises(KeyError):
        fab.remove_host(99)
    fab.remove_host(1)
    with pytest.raises(ValueError):
        fab.remove_host(0)


def test_rebalance_ingest_respects_write_shield():
    """`TieredStore.ingest` (the rebalance placement) parks its write
    while the destination tier has a read burst in flight — Flashield
    shielding applies to rebalance traffic exactly like demotions."""
    store = TieredStore(_pinned(), clock=VirtualClock(),
                        write_shield_depth=1)
    store.put("a", np.ones(1 << 16, np.uint8), tier=Tier.FLASH)
    store.runtime.drain()
    pf = store.get_async("a")                   # flash read in flight
    store.ingest("b", np.zeros(1 << 16, np.uint8), tier=Tier.FLASH)
    assert store.tier_of("b") == Tier.FLASH     # structurally placed...
    assert store.deferred_writes_pending == 1   # ...queue charge parked
    assert store.stats[Tier.FLASH].rebalance_deferred == 1
    assert store.stats[Tier.FLASH].demotions_deferred == 0  # stat pure
    pf.wait()                        # burst drains -> wait's flush fires
    assert store.deferred_writes_pending == 0


def test_shielded_ingest_preserves_nic_gate():
    """A shielded ingest parks its upstream-delivery gate with the
    write: flushing after the burst drains must still not start the
    write before the NIC transfer would have delivered the bytes."""
    store = TieredStore(_pinned(), clock=VirtualClock(),
                        write_shield_depth=1)
    store.put("a", np.ones(1 << 16, np.uint8), tier=Tier.FLASH)
    store.runtime.drain()
    pf = store.get_async("a")                   # shields FLASH
    gate = pf.transfer.done_t + 1.0             # NIC delivery far out
    store.ingest("b", np.zeros(1 << 16, np.uint8), tier=Tier.FLASH,
                 not_before=gate)
    assert store.deferred_writes_pending == 1
    pf.wait()                                   # drains burst + flushes
    assert store.deferred_writes_pending == 0
    writes = [tr for tr in store.runtime._inflight[Tier.FLASH]
              if tr.kind == "write" and tr.key == "b"]
    assert writes and writes[0].start_t >= gate


def test_churn_same_turn_join_then_leave():
    """join_turn == leave_turn performs BOTH events (grow, then the
    newest host departs) instead of one silently shadowing the other."""
    r = multi_host_session_bench(
        "async", n_hosts=4, n_sessions=8, rounds=2, kv_bytes=1 << 18,
        decode_steps=4, step_time=2e-3, lead=6, skew=0.0, seed=0,
        churn={"join_turn": 8, "leave_turn": 8})
    assert r["rebalances"] == 2.0
    assert r["final_hosts"] == 4.0


def test_leave_streams_park_on_bursting_survivor():
    """A host departure streams keys onto survivors; writes bound for a
    survivor with a read burst in flight park behind its shield."""
    fab = _fabric(3, write_shield_depth=1)
    for i in range(60):
        fab.put(("k", i), np.zeros(1 << 12, np.uint8), tier=Tier.FLASH,
                from_host=i % 3)
    fab.drain()
    victim = fab.host_ids[-1]
    survivors = [h for h in fab.host_ids if h != victim]
    # a read in flight on every survivor shields them all
    bursts = [fab.hosts[h].get_async(next(k for k in fab.hosts[h].keys()))
              for h in survivors]
    rb = fab.remove_host(victim)
    assert rb.keys_moved > 0
    assert sum(fab.hosts[h].deferred_writes_pending
               for h in survivors) == rb.keys_moved
    for pf in bursts:
        pf.wait()
    fab.drain()
    assert all(fab.hosts[h].deferred_writes_pending == 0
               for h in survivors)


# ---------------------------------------------------------------------------
# topology-aware NetQueueModel (rack/spine + incast)
# ---------------------------------------------------------------------------

def test_topology_rack_vs_spine_service():
    topo = FabricTopology(hosts_per_rack=2, rack_rtt=10e-6,
                          spine_rtt=50e-6, rack_bandwidth=10e9,
                          spine_bandwidth=5e9)
    m = NetQueueModel(topology=topo)
    rack = m.service(1 << 20, 4, src=0, dst=1)
    spine = m.service(1 << 20, 4, src=0, dst=2)
    assert rack.latency == 10e-6 and spine.latency == 50e-6
    assert spine.occupancy == pytest.approx(2 * rack.occupancy)
    # without src/dst context the uniform link answers (ctx-free callers)
    uni = m.service(1 << 20, 4)
    assert uni.latency == m.rtt


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=63),
       st.integers(min_value=1, max_value=1 << 22))
def test_topology_incast_degrades_monotonically(fan_in, extra, nbytes):
    topo = FabricTopology(hosts_per_rack=4, incast_degree=2)
    m = NetQueueModel(topology=topo)
    lo = m.service(nbytes, 4, src=0, dst=5, fan_in=fan_in)
    hi = m.service(nbytes, 4, src=0, dst=5, fan_in=fan_in + extra)
    assert hi.total >= lo.total                 # incast never helps
    assert hi.latency == lo.latency             # penalty is bandwidth
    if fan_in >= topo.incast_degree and extra > 0:
        assert hi.occupancy > lo.occupancy      # strictly past the knee


def test_topology_fabric_remote_fetch_rack_faster_than_spine():
    topo = FabricTopology(hosts_per_rack=2, rack_rtt=10e-6,
                          spine_rtt=200e-6, rack_bandwidth=12.5e9,
                          spine_bandwidth=2e9)
    fab = _fabric(4, topology=topo)
    key = next(("k", i) for i in range(64)
               if fab.owner(("k", i)) == 0)
    fab.put(key, np.zeros(1 << 20, np.uint8), tier=Tier.FLASH,
            from_host=0)
    fab.drain()
    clock = fab.clock
    t0 = clock.now()
    fab.get(key, from_host=1)                   # same rack as owner 0
    t_rack = clock.now() - t0
    fab.drain()
    t0 = clock.now()
    fab.get(key, from_host=2)                   # across the spine
    t_spine = clock.now() - t0
    assert t_spine > t_rack > 0


def test_topology_alongside_net_model_rejected():
    with pytest.raises(ValueError):
        _fabric(2, net_model=NetQueueModel(),
                topology=FabricTopology())
    with pytest.raises(ValueError):
        FabricTopology(spine_rtt=1e-6, rack_rtt=2e-6)


# ---------------------------------------------------------------------------
# p99-sized prefetch leads
# ---------------------------------------------------------------------------

_SEEDED = dict(n_hosts=4, n_sessions=8, rounds=2, kv_bytes=1 << 19,
               decode_steps=8, step_time=2e-3)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("skew", [0.0, 1.2])
def test_p99_lead_never_increases_stall_on_seeded_schedules(seed, skew):
    fixed = multi_host_session_bench("async", lead=6, seed=seed,
                                     skew=skew, **_SEEDED)
    sized = multi_host_session_bench("async", lead="p99", seed=seed,
                                     skew=skew, **_SEEDED)
    assert sized["tokens"] == fixed["tokens"]
    assert sized["per_token_stall"] <= fixed["per_token_stall"] + 1e-12


def test_p99_lead_beats_undersized_fixed_lead():
    """At 100us steps a 1-step fixed lead cannot cover the composed
    remote fetch; the p99-sized lead measures what it must cover and
    issues correspondingly earlier."""
    kw = dict(n_hosts=4, n_sessions=8, rounds=2, kv_bytes=1 << 21,
              decode_steps=32, step_time=1e-4, skew=0.0, seed=0)
    short = multi_host_session_bench("async", lead=1, **kw)
    sized = multi_host_session_bench("async", lead="p99", **kw)
    assert sized["per_token_stall"] < short["per_token_stall"]


def test_p99_lead_single_host_bench():
    r = multi_turn_session_bench("async", n_sessions=4, rounds=1,
                                 kv_bytes=1 << 20, decode_steps=8,
                                 step_time=2e-3, lead="p99")
    assert r["prefetch_hits"] > 0
    assert r["per_token_stall"] < multi_turn_session_bench(
        "sync", n_sessions=4, rounds=1, kv_bytes=1 << 20,
        decode_steps=8, step_time=2e-3)["per_token_stall"]


def test_prefetch_lead_steps_covers_estimate():
    fab = _fabric(2)
    key = ("kv", "s0")
    fab.put(key, np.zeros(1 << 20, np.uint8), tier=Tier.FLASH,
            from_host=fab.owner(key))
    fab.drain()
    other = next(h for h in fab.host_ids if h != fab.owner(key))
    est = fab.estimate_fetch_seconds(key, from_host=other)
    lead = fab.prefetch_lead_steps(key, 2e-3, from_host=other)
    assert lead >= 1 and lead * 2e-3 >= est
    # remote estimate strictly exceeds the owner-local one (NIC leg)
    assert est > fab.estimate_fetch_seconds(key, from_host=fab.owner(key))
    # p99-aware: the flash estimate dominates the mean-latency service
    store = fab.hosts[fab.owner(key)]
    svc = store.runtime.models[Tier.FLASH].service(1 << 20, 1)
    assert store.estimate_fetch_seconds(key) >= svc.occupancy + svc.latency


def test_engine_prefetch_lead_on_fabric_view():
    """DecodeEngine-style lead sizing through the HostView facade works
    without a real engine (duck-typed store contract)."""
    fab = _fabric(2)
    key = ("kv", "r1")
    fab.put(key, np.zeros(1 << 18, np.uint8), tier=Tier.FLASH,
            from_host=fab.owner(key))
    fab.drain()
    other = next(h for h in fab.host_ids if h != fab.owner(key))
    view = fab.host_view(other)
    assert view.prefetch_lead_steps(key, 2e-3) >= 1
    assert view.estimate_fetch_seconds(key) == \
        fab.estimate_fetch_seconds(key, from_host=other)


# ---------------------------------------------------------------------------
# locality-aware routing
# ---------------------------------------------------------------------------

def test_locality_routing_turns_remote_restores_local():
    base = multi_host_session_bench("async", lead=6, seed=0, skew=1.2,
                                    **_SEEDED)
    local = multi_host_session_bench("async", lead=6, seed=0, skew=1.2,
                                     locality=True, **_SEEDED)
    assert base["remote_fetches"] > 0
    assert local["remote_fetches"] == 0            # every restore local
    assert local["locality_hits"] == local["tokens"] / _SEEDED[
        "decode_steps"]
    assert local["per_token_stall"] <= base["per_token_stall"] + 1e-12


def test_preferred_host_is_first_holder_else_default():
    fab = _fabric(3)
    key = ("kv", "x")
    assert fab.preferred_host(key) is None
    assert fab.preferred_host(key, default=2) == 2
    fab.put(key, np.zeros(256, np.uint8), tier=Tier.FLASH,
            from_host=fab.owner(key))
    assert fab.preferred_host(key, default=2) == fab.owner(key)


def test_route_session_picks_replica_holder():
    from repro.serving.engine import route_session

    class FakeEngine:
        def __init__(self, fab, host):
            self.store = fab.host_view(host)
            self.host = host
            self.imported = {}

        locality_host = None  # replaced below

        def import_session(self, rid, state):
            self.imported[rid] = state

    # borrow DecodeEngine's implementation for the fake
    from repro.serving.engine import DecodeEngine
    FakeEngine.locality_host = DecodeEngine.locality_host

    fab = _fabric(3)
    rid = next(f"s{i}" for i in range(64)
               if fab.owner(("kv", f"s{i}")) == fab.host_ids[1])
    fab.put(("kv", rid), np.zeros(256, np.uint8), tier=Tier.FLASH,
            from_host=fab.host_ids[1])
    engines = {h: FakeEngine(fab, h) for h in fab.host_ids}
    target = route_session(engines, rid, state=("meta",))
    assert target.host == fab.host_ids[1]          # the KV holder
    assert target.imported[rid] == ("meta",)
    # unknown session falls back to the first engine, no import crash
    assert route_session(engines, "never-paused").host == fab.host_ids[0]


def test_expert_store_locality_host():
    from repro.tiering.expert_store import ExpertStore
    fab = _fabric(3)
    es = ExpertStore(n_layers=1, n_experts=4, policy=_pinned(),
                     store=fab.host_view(0))
    es.store.put((0, 0), np.zeros(128, np.float32), tier=Tier.FLASH)
    fab.drain()
    assert es.locality_host(0, 0) == fab.owner((0, 0))
    assert es.locality_host(0, 3) == 0             # absent -> own host
    assert es.prefetch_lead_steps(0, 0, 2e-3) >= 1


# ---------------------------------------------------------------------------
# explicit stats reset (TierStats reuse fix)
# ---------------------------------------------------------------------------

def test_reset_stats_clears_deferral_counters_not_state():
    clock = VirtualClock()
    store = TieredStore(_pinned(), specs={
        Tier.HBM: TierSpec(1 << 20, 819e9, 1e-7),
        Tier.DRAM: TierSpec(2 << 20, 45e9, 5e-7),
        Tier.FLASH: TierSpec(1 << 30, 7e9, 2e-5),
    }, clock=clock, write_shield_depth=1)
    store.put(("c", 0), np.ones(1 << 18, np.uint8), tier=Tier.FLASH)
    store.runtime.drain()
    pf = store.get_async(("c", 0))
    store.put(("h", 0), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    store.put(("h", 1), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    store.put(("h", 2), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    st_ = store.stats[Tier.FLASH]
    assert st_.demotions_deferred > 0 and st_.deferred_bytes > 0
    parked = store.deferred_writes_pending
    assert parked > 0
    store.reset_stats()
    st_ = store.stats[Tier.FLASH]
    assert st_.demotions_deferred == 0 and st_.deferred_bytes == 0
    assert st_.bytes_written == 0 and st_.demotions == 0
    assert store.runtime.qstats[Tier.FLASH].submitted == 0
    # structural state survives: residency, parked writes, in-flight
    assert store.deferred_writes_pending == parked
    assert store.tier_of(("c", 0)) is not None
    pf.wait()                                      # burst drains...
    assert store.deferred_writes_pending == 0      # ...writes flush


def test_fabric_reset_stats_spans_hosts_nics_and_counters():
    fab = _fabric(2)
    key = ("kv", "s0")
    fab.put(key, np.zeros(1 << 18, np.uint8), tier=Tier.FLASH,
            from_host=fab.owner(key))
    fab.drain()
    fab.get(key, from_host=next(h for h in fab.host_ids
                                if h != fab.owner(key)))
    assert fab.remote_fetches == 1
    assert any(n.qstats[NIC].submitted for n in fab.nic.values())
    fab.reset_stats()
    assert fab.remote_fetches == fab.local_fetches == fab.remote_puts == 0
    assert all(n.qstats[NIC].submitted == 0 for n in fab.nic.values())
    assert all(st.bytes_read == 0 for s in fab.hosts.values()
               for st in s.stats.values())
    assert fab.tier_of(key) == Tier.FLASH          # residency untouched


# ---------------------------------------------------------------------------
# fleet benchmark determinism, churn schedule included (CI gate promoted
# into the suite)
# ---------------------------------------------------------------------------

def _load_fleet_cli():
    path = pathlib.Path(__file__).resolve().parents[1] / \
        "benchmarks" / "serving_fleet.py"
    spec = importlib.util.spec_from_file_location("serving_fleet", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fleet_smoke_with_churn_byte_identical_in_process():
    fleet = _load_fleet_cli()
    kw = dict(n_sessions=8, rounds=2, kv_bytes=1 << 18, decode_steps=4,
              step_time=2e-3, lead="p99", seed=0, locality=True,
              churn={"join_turn": 8, "leave_turn": 14})
    a = fleet.run_sweep([4], [0.0, 1.2], **kw)
    b = fleet.run_sweep([4], [0.0, 1.2], **kw)
    ja, jb = (json.dumps(x, sort_keys=True) for x in (a, b))
    assert ja == jb
    for rec in a:
        ch = rec["churn"]
        assert ch["rebalance_bytes"] > 0           # the join moved keys
        assert ch["churn"]["final_hosts"] == 4.0   # join then leave
        assert ch["churn"]["rebalances"] == 2.0
        # the rebalance tax is bounded in absolute terms (the 2x-ratio
        # acceptance bound lives on the CLI scenario, where the locality
        # -free baseline stall is not near zero)
        assert ch["added_stall_per_token"] < 2e-3  # well under one step


def test_churn_join_moves_about_one_fifth_and_stays_within_2x():
    """The CLI acceptance scenario in-process: 4->5 join mid-schedule,
    rebalance bytes ~ 1/5 of resident, stall within 2x of no-churn."""
    c = compare_churn({"join_turn": 32}, n_hosts=4, n_sessions=32,
                      rounds=2, kv_bytes=1 << 18, decode_steps=8,
                      step_time=2e-3, lead=6, skew=0.0, seed=0)
    assert c["rebalance_fraction"] == pytest.approx(1 / 5, abs=0.10)
    assert c["stall_ratio"] <= 2.0
    assert c["churn"]["final_hosts"] == 5.0
    assert c["baseline"]["rebalances"] == 0.0
