"""Optimizer tests: AdamW semantics, schedules, clipping, and the int8
error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import (AdamWConfig, apply_updates, compress_int8,
                               global_norm, init_state, schedule_lr)


def _toy_params():
    return {"w": jnp.ones((4, 4)) * 0.5, "b": jnp.zeros((4,))}


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    params = _toy_params()
    state = init_state(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 0.1 * l0


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    lr5 = float(schedule_lr(cfg, jnp.asarray(5)))
    lr10 = float(schedule_lr(cfg, jnp.asarray(10)))
    lr110 = float(schedule_lr(cfg, jnp.asarray(110)))
    assert abs(lr5 - 0.5) < 1e-6
    assert abs(lr10 - 1.0) < 1e-6
    assert abs(lr110 - 0.1) < 1e-3


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(peak_lr=1e-2, clip_norm=1.0, warmup_steps=1,
                      weight_decay=0.0)
    params = _toy_params()
    state = init_state(params, cfg)
    huge = jax.tree.map(lambda p: jnp.full_like(p, 1e6), params)
    _, _, m = apply_updates(params, huge, state, cfg)
    assert float(m["grad_norm"]) > 1e6          # reported pre-clip
    # post-clip effective norm is 1.0 -> first-step update ~ lr * sign
    new, _, _ = apply_updates(params, huge, state, cfg)
    delta = global_norm(jax.tree.map(lambda a, b: a - b, params, new))
    assert float(delta) < 1.0


def test_int8_compression_error_feedback_is_lossless_in_the_limit():
    """EF property: accumulated (deq + err) == accumulated true gradient."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = compress_int8(g_true, err)
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 50),
                               np.asarray(g_true), atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), scale=st.floats(1e-6, 1e4))
def test_int8_quantization_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    deq, err = compress_int8(g, jnp.zeros_like(g))
    # per-element error bounded by one quantization step
    step = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= step + 1e-12


def test_compressed_training_still_descends():
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, compress_bits=8)
    params = _toy_params()
    state = init_state(params, cfg)
    assert "err" in state

    def loss(p):
        return jnp.sum((p["w"] - 0.1) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 0.2 * l0
