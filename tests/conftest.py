"""Suite-wide fixtures and dependency guards.

`hypothesis` is a dev-only dependency (requirements-dev.txt); some
execution environments pin a base image without it. Rather than letting
five modules die at collection with ModuleNotFoundError, install the
deterministic fallback shim (tests/_hypothesis_shim.py) so the property
tests still collect and run on generated inputs everywhere.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    import _hypothesis_shim as _shim

    mod = types.ModuleType("hypothesis")
    mod.given = _shim.given
    mod.settings = _shim.settings
    mod.assume = _shim.assume
    mod.strategies = _shim.strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = _shim.strategies
