"""Autopilot tier: reuse-sketch kernel vs numpy oracle, ghost-cache
tracking, EconomicGate admission/hysteresis, readability gating,
rebalance pacing, replica-aware routing, the MoE decode pipeline, and
the serving_autopilot benchmark's determinism + win criterion."""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.autopilot import EconomicGate, ReuseTracker
from repro.autopilot.advisor import ProvisionAdvisor
from repro.autopilot.bench import compare_scenario, run_scenario, run_suite
from repro.autopilot.gate import default_classify
from repro.autopilot.traces import SCENARIOS, generate
from repro.core.economics import GPU_GDDR
from repro.core.policy import Tier, TieringPolicy
from repro.core.ssd_model import storage_next_ssd
from repro.kernels.reuse_sketch.ops import reuse_sketch_update
from repro.kernels.reuse_sketch.ref import reference_reuse_sketch
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import ShardedTieredStore
from repro.runtime.tiers import TierSpec, TieredStore


# ---------------------------------------------------------------------------
# reuse-sketch kernel vs numpy oracle
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 600), c=st.integers(1, 6),
       b=st.sampled_from([8, 24, 32]), seed=st.integers(0, 2**16))
def test_reuse_sketch_matches_oracle(n, c, b, seed):
    rng = np.random.default_rng(seed)
    hist = (rng.random((c, b)) * 7).astype(np.float32)
    iv = np.exp(rng.normal(0.0, 4.0, n)).astype(np.float32)
    iv[rng.random(n) < 0.15] = 0.0            # first-touch / padding slots
    cls = rng.integers(-1, c + 1, n).astype(np.int32)   # incl. off-range
    out = np.asarray(reuse_sketch_update(hist, iv, cls,
                                         tau0=1e-3, decay=0.97))
    ref = reference_reuse_sketch(hist, iv, cls, tau0=1e-3, decay=0.97)
    # bucket counts are tolerance-exact: subtract the decayed carry-over
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-6)
    counts = out - np.float32(0.97) * hist
    ref_counts = ref - np.float32(0.97) * hist
    np.testing.assert_allclose(np.round(counts), np.round(ref_counts))
    assert counts.sum() == pytest.approx(ref_counts.sum(), abs=1e-3)


def test_reuse_sketch_empty_batch_decays_only():
    hist = np.full((2, 8), 4.0, np.float32)
    out = np.asarray(reuse_sketch_update(
        hist, np.zeros(0), np.zeros(0, np.int32), tau0=1e-3, decay=0.5))
    np.testing.assert_allclose(out, 2.0, atol=1e-6)


def test_reuse_sketch_padding_invariant():
    """The padded launch width must not change the result."""
    hist = np.zeros((2, 16), np.float32)
    iv = np.asarray([0.01, 0.5, 3.0], np.float32)
    cls = np.asarray([0, 1, 0], np.int32)
    a = np.asarray(reuse_sketch_update(hist, iv, cls, tau0=1e-3,
                                       decay=1.0, batch_pad=4))
    b = np.asarray(reuse_sketch_update(hist, iv, cls, tau0=1e-3,
                                       decay=1.0, batch_pad=512))
    np.testing.assert_array_equal(a, b)
    assert a.sum() == 3.0


# ---------------------------------------------------------------------------
# ReuseTracker (ghost + sketch)
# ---------------------------------------------------------------------------

def test_tracker_ghost_measures_reuse_and_bounds_size():
    tr = ReuseTracker(ghost_capacity=4)
    assert tr.observe("a", "kv", now=1.0) is None      # first touch
    assert tr.observe("a", "kv", now=3.0) == pytest.approx(2.0)
    for i in range(6):                                  # evict "a"
        tr.observe(("k", i), "kv", now=4.0 + i)
    assert tr.last_seen("a") is None
    assert tr.observe("a", "kv", now=20.0) is None      # ghost forgot
    assert len(tr._last_seen) <= 4


def test_tracker_class_quantile_tracks_interval_scale():
    tr = ReuseTracker(tau0=1e-3, decay=1.0)
    for i in range(20):
        tr.observe("hot", "kv", now=0.1 * i)            # ~100ms reuse
        tr.observe("cold", "scan", now=50.0 * i)        # ~50s reuse
    q_kv = tr.class_quantile("kv")
    q_scan = tr.class_quantile("scan")
    assert 0.05 < q_kv < 0.3
    assert q_scan > 25.0
    assert tr.class_quantile("never") is None
    assert tr.interval_samples("kv").size > 0
    assert tr.interval_samples("never").size == 0


def test_tracker_kernel_path_matches_oracle_path():
    """`use_kernel=True` routes batch updates through the Pallas sketch
    kernel; the resulting histogram matches the numpy-oracle tracker."""
    trs = [ReuseTracker(use_kernel=k, decay=0.9) for k in (False, True)]
    rng = np.random.default_rng(7)
    for t in range(4):
        keys = [("kv", int(i)) for i in rng.integers(0, 12, 16)]
        for tr in trs:
            tr.observe_batch(keys, ["kv"] * len(keys), now=0.3 * t)
    np.testing.assert_allclose(trs[0].hist, trs[1].hist,
                               atol=1e-5, rtol=1e-6)
    assert trs[0].measured == trs[1].measured > 0


def test_tracker_batch_observation_and_decay():
    tr = ReuseTracker(decay=0.5)
    tr.observe_batch(["a", "b"], ["kv", "kv"], now=0.0)
    iv = tr.observe_batch(["a", "b"], ["kv", "kv"], now=1.0)
    assert (iv > 0).all()
    mass = tr.class_mass("kv")
    tr.observe_batch([], [], now=2.0)                   # decay only
    assert tr.class_mass("kv") == pytest.approx(mass * 0.5)


# ---------------------------------------------------------------------------
# EconomicGate
# ---------------------------------------------------------------------------

def _specs(l=1 << 16):
    return {
        Tier.HBM: TierSpec(2 * l, 819e9, 1e-7),
        Tier.DRAM: TierSpec(8 * l, 45e9, 5e-7),
        Tier.FLASH: TierSpec(1 << 30, 7e9, 2e-5),
    }


def test_gate_cold_default_then_prior_then_measured():
    clock = VirtualClock()
    gate = EconomicGate(tau_hot=0.01, tau_be=1.0)
    store = TieredStore(gate, specs=_specs(), clock=clock)
    blob = np.zeros(1 << 14, np.uint8)
    # unknown key, unknown class -> cold default
    store.put(("kv", 0), blob)
    assert store.tier_of(("kv", 0)) == Tier.FLASH
    assert gate.gate_stats.cold_defaults == 1
    # measured fast reuse -> class prior forms; new kv keys admit to DRAM
    for t in range(1, 8):
        clock.advance(0.1)
        store.get(("kv", 0))
    store.put(("kv", 1), blob)
    assert store.tier_of(("kv", 1)) == Tier.DRAM
    assert gate.gate_stats.prior_decisions >= 1
    # ghost-measured readmission: a once-seen key (no EMA yet) leaves
    # and comes back fast -> the ghost prices it, not the class prior
    store.delete(("kv", 1))
    clock.advance(0.05)
    store.put(("kv", 1), blob)
    assert store.tier_of(("kv", 1)) == Tier.DRAM
    assert gate.gate_stats.readmits_measured >= 1
    # an explicitly colder request wins over the gate's admit
    store.put(("kv", 2), blob, tier=Tier.FLASH)
    assert store.tier_of(("kv", 2)) == Tier.FLASH


def test_gate_default_classify():
    assert default_classify(("kv", "s0")) == "kv"
    assert default_classify((3, 7)) == "expert"
    assert default_classify("plain") == "obj"


def test_gate_no_oscillation_on_constant_interval_trace():
    """A key reused at a constant interval inside the hysteresis band
    around tau_be must settle into one tier and stay — no admit/demote
    ping-pong."""
    for iv in (0.9, 1.0, 1.1):          # below / at / above tau_be
        clock = VirtualClock()
        gate = EconomicGate(tau_hot=1e-3, tau_be=1.0, hysteresis=0.25)
        store = TieredStore(gate, specs=_specs(), clock=clock)
        store.put("k", np.zeros(1 << 14, np.uint8))
        moves_after_warmup = 0
        for t in range(40):
            clock.advance(iv)
            store.get("k")
            if t == 10:
                base = (sum(s.promotions for s in store.stats.values()),
                        sum(s.demotions for s in store.stats.values()))
        end = (sum(s.promotions for s in store.stats.values()),
               sum(s.demotions for s in store.stats.values()))
        moves_after_warmup = (end[0] - base[0]) + (end[1] - base[1])
        assert moves_after_warmup == 0, f"oscillation at interval {iv}"


def test_gate_evicts_stale_squatters_before_active_keys():
    clock = VirtualClock()
    gate = EconomicGate(tau_hot=1e-3, tau_be=10.0)
    # squatter: hot yesterday (small EMA), untouched since
    for t in (0.0, 0.5, 1.0):
        gate.observe("squatter", now=t)
    for t in np.arange(1.0, 60.0, 2.0):
        gate.observe("active", now=float(t))
    order = gate.evict_candidates(Tier.DRAM, now=60.0)
    assert order.index("squatter") < order.index("active")
    with pytest.raises(ValueError):
        gate.evict_candidates(Tier.DRAM)        # explicit clock required
    with pytest.raises(ValueError):
        gate.observe("x")


def test_gate_from_break_even_stall_term_widens_threshold():
    host, ssd = GPU_GDDR, storage_next_ssd()
    plain = EconomicGate.from_break_even(host, ssd, 1 << 17)
    priced = EconomicGate.from_break_even(host, ssd, 1 << 17,
                                          alpha_stall=4.0,
                                          fetch_seconds=3e-4)
    assert priced.tau_be > plain.tau_be > 0


# ---------------------------------------------------------------------------
# readability gating (mid-rebalance restores priced conservatively)
# ---------------------------------------------------------------------------

def _pinned(_h=0):
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def test_ingest_arrival_gates_reads_until_delivery():
    clock = VirtualClock()
    store = TieredStore(_pinned(), clock=clock)
    arrival = 0.5
    store.ingest("k", np.zeros(1 << 12, np.uint8), tier=Tier.FLASH,
                 not_before=arrival)
    t0 = clock.now()
    store.get("k")                       # demand read during the stream
    assert clock.now() >= arrival        # waited for the wire
    assert clock.now() - t0 >= arrival - t0
    # after delivery the gate is gone: a fresh read is served normally
    pf = store.get_async("k")
    assert pf.transfer.start_t >= arrival
    pf.wait()
    before = clock.now()
    store.get("k")
    assert clock.now() - before < arrival          # plain flash service


def test_put_supersedes_pending_arrival():
    clock = VirtualClock()
    store = TieredStore(_pinned(), clock=clock)
    store.ingest("k", np.zeros(1 << 12, np.uint8), tier=Tier.FLASH,
                 not_before=5.0)
    assert store._arrival_gate("k") == 5.0
    # a fresh local write supersedes the in-flight wire copy: reads are
    # no longer gated on the stale delivery horizon
    store.put("k", np.zeros(1 << 12, np.uint8), tier=Tier.FLASH)
    assert store._arrival_gate("k") is None
    # and once a gate's horizon passes, it prunes itself
    store.ingest("k2", np.zeros(1 << 12, np.uint8), tier=Tier.FLASH,
                 not_before=1.0)
    clock.advance(2.0)
    assert store._arrival_gate("k2") is None


def test_rebalanced_key_restore_waits_for_nic_delivery():
    fab = ShardedTieredStore(4, policy_factory=_pinned,
                             clock=VirtualClock())
    blob = np.zeros(1 << 16, np.uint8)
    for i in range(64):
        fab.put(("k", i), blob, tier=Tier.FLASH, from_host=i % 4)
    fab.drain()
    before = {i: fab.owner(("k", i)) for i in range(64)}
    t_join = fab.clock.now()
    fab.add_host()
    moved = [i for i in range(64) if fab.owner(("k", i)) != before[i]]
    assert moved
    # a restore of a just-moved key cannot be served before its stream
    # (source flash read + NIC leg) delivers: strictly after join time
    t0 = fab.clock.now()
    fab.get(("k", moved[0]), from_host=fab.owner(("k", moved[0])))
    assert fab.clock.now() > t0
    stalled = fab.clock.now() - t0
    svc_only = fab.hosts[fab.owner(("k", moved[0]))]
    assert stalled > 0
    assert t_join == t0                  # nothing else advanced the clock


# ---------------------------------------------------------------------------
# rebalance pacing (token bucket)
# ---------------------------------------------------------------------------

def test_rebalance_pacing_spaces_stream_reads():
    def build(rate):
        fab = ShardedTieredStore(2, policy_factory=_pinned,
                                 clock=VirtualClock(),
                                 rebalance_rate=rate)
        for i in range(48):
            fab.put(("k", i), np.zeros(1 << 16, np.uint8),
                    tier=Tier.FLASH, from_host=i % 2)
        fab.drain()
        rb = fab.add_host()
        t_end = fab.drain()
        return rb, t_end

    rb_fast, t_fast = build(None)
    rate = 2e6                            # 2 MB/s: clearly binding
    rb_slow, t_slow = build(rate)
    assert rb_slow.bytes_moved == rb_fast.bytes_moved > 0
    # the paced stream cannot finish faster than the bucket drains the
    # busiest source's bytes (~half the moved bytes on two sources)
    assert t_slow > t_fast
    assert t_slow >= rb_slow.bytes_moved / (2 * rate)


def test_rebalance_rate_validation():
    with pytest.raises(ValueError):
        ShardedTieredStore(2, rebalance_rate=0.0)


# ---------------------------------------------------------------------------
# replica-aware load balancing
# ---------------------------------------------------------------------------

def test_preferred_host_spreads_by_queue_depth():
    fab = ShardedTieredStore(3, policy_factory=_pinned,
                             clock=VirtualClock())
    key = ("kv", "hot")
    fab.put(key, np.zeros(1 << 16, np.uint8), tier=Tier.FLASH,
            from_host=0, replicas=2)
    fab.drain()
    holders = fab.holders(key)
    assert len(holders) == 2
    # idle fleet: ring order wins (the single-replica behavior)
    assert fab.preferred_host(key) == holders[0]
    # load the first holder's flash queue -> routing moves to the second
    busy = [fab.hosts[holders[0]].get_async(key) for _ in range(4)]
    assert fab.preferred_host(key) == holders[1]
    for pf in busy:
        pf.wait()
    fab.drain()
    assert fab.preferred_host(key) == holders[0]


# ---------------------------------------------------------------------------
# MoE decode pipeline (prefetch_experts wired through the gate)
# ---------------------------------------------------------------------------

def test_expert_decode_step_pipelines_prefetch():
    from repro.tiering.expert_store import ExpertStore

    def run(pipelined):
        clock = VirtualClock()
        gate = EconomicGate(tau_hot=1e-4, tau_be=0.5)
        es = ExpertStore(n_layers=4, n_experts=8, policy=gate,
                         clock=clock)
        for layer in range(4):
            for e in range(8):
                es.store.put((layer, e), np.zeros(1 << 16, np.float32),
                             tier=Tier.FLASH)
        es.store.runtime.drain()
        es.store.reset_stats()
        rng = np.random.default_rng(0)
        stall = 0.0
        for _ in range(12):
            routings = {l: rng.integers(0, 8, 2) for l in range(4)}
            if pipelined:
                stall += es.decode_step(routings, layer_time=2e-3)["stall"]
            else:
                for l in sorted(routings):
                    for e in np.unique(routings[l]):
                        t0 = clock.now()
                        es.fetch_expert(l, int(e))
                        stall += clock.now() - t0
                    es.store.runtime.advance(2e-3)
        return stall, es

    stall_pipe, es = run(True)
    stall_sync, _ = run(False)
    assert stall_pipe < stall_sync
    # the gate tracked every routing: the expert class has measured mass
    assert es.policy.tracker.class_mass("expert") > 0


# ---------------------------------------------------------------------------
# traces + benchmark determinism + the acceptance criterion
# ---------------------------------------------------------------------------

def test_traces_deterministic_and_scenario_shaped():
    for name in SCENARIOS:
        a = generate(name, n_steps=60, seed=3)
        b = generate(name, n_steps=60, seed=3)
        assert a.steps == b.steps
        assert a.accesses > 0
    flood = generate("scan_flood", n_steps=90, seed=0)
    scans = [k for k in flood.distinct_keys() if k[0] == "scan"]
    counts = {}
    for step in flood.steps:
        for k in step:
            counts[k] = counts.get(k, 0) + 1
    assert scans and all(counts[k] == 1 for k in scans)   # one-touch
    with pytest.raises(ValueError):
        generate("nope")


def test_autopilot_bench_deterministic_in_process():
    kw = dict(n_steps=60, seed=0)
    a = run_scenario("zipf", "economic", **kw)
    b = run_scenario("zipf", "economic", **kw)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_autopilot_gate_beats_static_baselines():
    """The acceptance criterion, in-process: the gate's modeled $/token
    does not exceed the best static baseline's, at equal-or-lower
    per-token stall, on >= 3 of the 4 scenarios."""
    report = run_suite(n_steps=120, seed=0)
    assert report["cells"] == 4
    assert report["wins"] >= 3
    for cell in report["scenarios"]:
        gate = cell["runs"]["economic"]
        flash = cell["runs"]["flash"]
        # the gate never loses to always-flash on either axis
        assert gate["cost_per_token"] <= flash["cost_per_token"]
        assert gate["per_token_stall"] <= flash["per_token_stall"]
        # and even where it loses the cell, it stays within a few %
        assert cell["cost_ratio_vs_best_static"] < 1.10
        assert gate["gate"]["admits_flash"] > 0     # the gate gated


def test_autopilot_advisor_separates_classes_and_recommends():
    rec = run_scenario("scan_flood", "economic", n_steps=90, seed=0)
    adv = rec["advice"]
    assert adv["classes"]["scan"]["hot_fraction"] == 0.0
    assert adv["classes"]["kv"]["hot_fraction"] > 0.3
    assert adv["recommended_dram_bytes"] >= adv["hot_bytes"] > 0
    assert adv["tau_be"] > 0
    assert rec["gate"]["cold_defaults"] > 0


def test_advisor_on_fabric_includes_rebalance():
    fab = ShardedTieredStore(2, policy_factory=_pinned,
                             clock=VirtualClock())
    tracker = ReuseTracker()
    for i in range(24):
        fab.put(("kv", i), np.zeros(1 << 14, np.uint8), tier=Tier.FLASH,
                from_host=i % 2)
    fab.drain()
    for t in range(6):
        for i in range(8):
            tracker.observe(("kv", i), "kv", now=float(t))
    fab.add_host()
    fab.drain()
    advisor = ProvisionAdvisor(GPU_GDDR, storage_next_ssd(), 1 << 14)
    advice = advisor.advise(tracker, fabric=fab)
    assert advice.rebalance is not None
    assert advice.rebalance["events"] == 1.0
    assert 0 < advice.rebalance["moved_fraction"] < 1.0
    assert advice.recommended_hosts >= 1
    with pytest.raises(ValueError):
        advisor.advise(tracker)                    # store xor fabric


def test_compare_scenario_reports_best_static():
    cell = compare_scenario("zipf", n_steps=40, seed=0)
    assert cell["best_static"] in ("dram", "flash")
    assert set(cell["runs"]) == {"economic", "dram", "flash"}


def test_bench_rejects_unknown_mode():
    with pytest.raises(ValueError):
        run_scenario("zipf", "lru", n_steps=10)


# ---------------------------------------------------------------------------
# small-surface coverage: tracker validation, advisor report/verdicts
# ---------------------------------------------------------------------------

def test_tracker_parameter_validation_and_histogram():
    with pytest.raises(ValueError):
        ReuseTracker(n_buckets=1)
    with pytest.raises(ValueError):
        ReuseTracker(decay=0.0)
    tr = ReuseTracker(max_classes=1)
    tr.observe("a", "kv", now=0.0)
    with pytest.raises(ValueError):
        tr.class_id("another")
    assert tr.histogram("kv") is not None
    assert tr.histogram("never") is None
    with pytest.raises(ValueError):
        reuse_sketch_update(np.zeros((1, 8), np.float32),
                            np.zeros(3), np.zeros(2, np.int32),
                            tau0=1e-3, decay=0.9)   # length mismatch


def test_advisor_report_renders_and_verdicts_cover_fit():
    clock = VirtualClock()
    tracker = ReuseTracker()
    store = TieredStore(_pinned(), specs=_specs(), clock=clock)
    blob = np.zeros(1 << 14, np.uint8)
    for i in range(4):
        store.put(("kv", i), blob, tier=Tier.DRAM)
    for t in range(1, 6):
        for i in range(4):
            tracker.observe(("kv", i), "kv", now=0.2 * t)
    clock.advance(1.0)
    advisor = ProvisionAdvisor(GPU_GDDR, storage_next_ssd(), 1 << 14)
    advice = advisor.advise(tracker, store=store)
    text = advice.report()
    assert "tau_be" in text and "VERDICT" in text and "kv" in text
    assert advice.hot_bytes > 0
    d = advice.as_dict()
    assert "rebalance" not in d            # none occurred
    # a tiny hot set against huge DRAM -> not capacity-limited
    assert advice.limit != "capacity" or advice.recommended_hosts >= 1
