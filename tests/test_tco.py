"""Tests for the beyond-paper TCO / multi-tier extension (paper §VIII)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SLC, storage_next_ssd
from repro.core.economics import classical_break_even
from repro.core.tco import (TierSpec, place, reference_tiers,
                            tco_break_even, tier_ladder)
from repro.core.ssd_model import iops_ssd_peak


def test_zero_power_reduces_to_classical_rule():
    """With OpEx zeroed, the TCO pair break-even equals the classical
    CapEx-only expression (amortization cancels)."""
    ssd = storage_next_ssd(SLC)
    l = 512
    iops = float(iops_ssd_peak(ssd, l, 9.0, 3.0))
    dram = TierSpec("DRAM", cost_per_byte=1 / 3e9, power_per_byte=0.0,
                    device_cost=1.0, device_iops=1e9, energy_per_io=0.0)
    flash = TierSpec("FLASH", cost_per_byte=ssd.cost / ssd.total_nand_bytes,
                     power_per_byte=0.0, device_cost=ssd.cost,
                     device_iops=iops, energy_per_io=0.0)
    tau_tco = tco_break_even(l, dram, flash, power_cost=0.0)
    tau_classical = float(classical_break_even(l, ssd.cost, iops,
                                               dram_cost_per_byte=1 / 3e9))
    assert tau_tco == pytest.approx(tau_classical, rel=1e-9)


def test_opex_moves_the_threshold_both_ways():
    """OpEx acts on BOTH sides: DRAM refresh power raises the rent
    (shortens tau), flash access energy raises the fetch cost (lengthens
    tau). At $0.10/kWh and 8uJ/IO the fetch energy dominates, so the full
    TCO threshold is LONGER than CapEx-only — i.e. energy accounting makes
    DRAM residency *more* attractive, a finding the CapEx-only paper
    cannot see."""
    import dataclasses
    ssd = storage_next_ssd(SLC)
    tiers = reference_tiers(ssd)
    dram, flash = tiers[1], tiers[3]
    capex_only = tco_break_even(512, dram, flash, power_cost=0.0)
    full = tco_break_even(512, dram, flash)
    assert full > capex_only                      # fetch-energy dominated
    # isolate the rent-side effect: zero the flash access energy
    flash_noe = dataclasses.replace(flash, energy_per_io=0.0)
    rent_only = tco_break_even(512, dram, flash_noe)
    assert rent_only < capex_only                 # refresh power shortens


def test_ladder_is_monotone_and_places_sanely():
    ssd = storage_next_ssd(SLC)
    tiers = reference_tiers(ssd)
    ladder = tier_ladder(512, tiers)
    names = [n for n, _ in ladder]
    assert names == ["HBM", "DRAM", "CXL-DRAM", "FLASH-SN"]
    taus = [t for _, t in ladder]
    assert all(a < b for a, b in zip(taus[:-1], taus[1:])), taus
    # microsecond reuse -> HBM; multi-minute reuse -> flash
    assert place(1e-6, ladder) == "HBM"
    assert place(3600.0, ladder) == "FLASH-SN"
    # something lands in each intermediate tier for some tau
    assert place(taus[0] * 2, ladder) in ("DRAM", "CXL-DRAM")


def test_cxl_threshold_between_dram_and_flash():
    """The CXL tier's upper threshold sits between DRAM's and flash's:
    it absorbs the reuse band DRAM is too expensive for and flash too
    slow/costly-per-IO for."""
    ssd = storage_next_ssd(SLC)
    ladder = dict(tier_ladder(512, reference_tiers(ssd)))
    assert ladder["DRAM"] > ladder["HBM"]
    assert ladder["CXL-DRAM"] > ladder["DRAM"]


def test_slower_fabric_grows_cxl_tier_value():
    """Worse CXL latency lowers its IOPS, pushing ITS break-even against
    flash upward only via io cost — check directional sensitivity."""
    ssd = storage_next_ssd(SLC)
    fast = dict(tier_ladder(512, reference_tiers(ssd, cxl_latency=200e-9)))
    slow = dict(tier_ladder(512, reference_tiers(ssd, cxl_latency=2e-6)))
    # DRAM->CXL boundary: fetching from slower CXL costs more per IO, so
    # data stays in DRAM longer
    assert slow["DRAM"] > fast["DRAM"]
