"""Self-healing fleet tier: unplanned `fail_host` (no drain), degraded
reads around dead holders (including in-flight remote fetches), the
paced repair loop restoring the declared replication degree, ghost/EMA
purging on key loss (no spurious re-admission evidence), torn-session
export guards, engine checkpoint -> failover -> resume equivalence with
the uninterrupted reference, availability pricing in the advisor, and
the kill-a-host-at-diurnal-peak benchmark's acceptance criteria
(byte-deterministic across in-process double runs)."""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.autopilot.gate import EconomicGate
from repro.autopilot.reuse import ReuseTracker
from repro.core.policy import Tier, TieringPolicy
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import ShardedTieredStore
from repro.runtime.repair import RepairLoop


def _pinned(_h=0):
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def _fabric(n_hosts, **kw):
    return ShardedTieredStore(n_hosts, policy_factory=_pinned,
                              clock=VirtualClock(), **kw)


# ---------------------------------------------------------------------------
# fail_host semantics + replica bookkeeping after unplanned shrink
# ---------------------------------------------------------------------------

def _sole_and_replicated(fab, n=40):
    """Populate with r=1 and r=2 keys; returns (sole, replicated)."""
    sole, repl = [], []
    for i in range(n):
        key = ("one", i)
        fab.put(key, np.full(64, i, np.int32), tier=Tier.FLASH,
                from_host=fab.owner(key))
        sole.append(key)
        key = ("two", i)
        fab.put(key, np.full(64, 1000 + i, np.int32), tier=Tier.FLASH,
                from_host=fab.owner(key), replicas=2)
        repl.append(key)
    fab.drain()
    return sole, repl


def test_fail_host_loses_sole_copies_and_keeps_replicated():
    fab = _fabric(3)
    sole, repl = _sole_and_replicated(fab)
    victim = fab.host_ids[0]
    dead_sole = [k for k in sole if fab.holders(k) == [victim]]
    assert dead_sole, "expected some r=1 keys homed on the victim"
    report = fab.fail_host(victim)
    assert report.host == victim and victim not in fab.host_ids
    assert set(report.lost_keys) == set(dead_sole)
    assert report.keys_lost == len(dead_sole)
    assert report.bytes_lost == sum(64 * 4 for _ in dead_sole)
    # replicated keys all survive and are readable (degraded ok)
    for i, key in enumerate(repl):
        assert fab.holders(key), f"{key} lost despite replicas=2"
        np.testing.assert_array_equal(
            fab.get(key, from_host=fab.host_ids[0]),
            np.full(64, 1000 + i, np.int32))
    for key in dead_sole:
        with pytest.raises(KeyError):
            fab.get(key, from_host=fab.host_ids[0])
    assert fab.summary()["failed_hosts"] == 1.0
    assert fab.summary()["keys_lost"] == float(len(dead_sole))


def test_fail_host_purges_stale_replica_bookkeeping():
    """Regression: `_key_replicas` must not keep entries for keys lost
    in a failure — a stale entry would make a later `put` of the same
    key plan replicas from dead state, and `holders()`/`_targets()`
    must never name the failed host."""
    fab = _fabric(3)
    sole, repl = _sole_and_replicated(fab)
    victim = fab.host_ids[0]
    dead_sole = [k for k in sole if fab.holders(k) == [victim]]
    fab.fail_host(victim)
    for key in dead_sole:
        assert key not in fab._key_replicas
    for key in repl:
        assert victim not in fab.holders(key)
        assert victim not in fab._targets(key)
    # a lost key re-put lands cleanly on the surviving ring
    key = dead_sole[0]
    fab.put(key, np.full(64, 7, np.int32), tier=Tier.FLASH,
            from_host=fab.host_ids[0], replicas=2)
    assert len(fab.holders(key)) == 2
    assert victim not in fab.holders(key)


def test_fail_host_guards():
    fab = _fabric(2)
    with pytest.raises(KeyError):
        fab.fail_host(99)
    fab.fail_host(fab.host_ids[0])
    with pytest.raises(ValueError):
        fab.fail_host(fab.host_ids[0])    # cannot fail the last host


# ---------------------------------------------------------------------------
# degraded reads: in-flight remote fetch survives its owner's failure
# ---------------------------------------------------------------------------

def _remote_setup(replicas):
    """3-host fabric, one key, an issued (in-flight) remote fetch from
    a non-holder host; returns (fab, key, pf, owner)."""
    fab = _fabric(3)
    key = ("kv", "s0")
    val = np.arange(4096, dtype=np.float32)
    fab.put(key, val, tier=Tier.FLASH, from_host=fab.owner(key),
            replicas=replicas)
    fab.drain()
    reader = next(h for h in fab.host_ids
                  if fab.hosts[h].tier_of(key) is None)
    pf = fab.get_async(key, from_host=reader)
    return fab, key, val, pf, pf.owner


def test_inflight_remote_fetch_falls_back_to_surviving_replica():
    """Regression: a RemoteFetch whose owner dies mid-transfer used to
    crash deep in the NIC wait; with replicas>=2 it must transparently
    re-issue against a surviving holder and return the right bytes."""
    fab, key, val, pf, owner = _remote_setup(replicas=2)
    assert pf.nic_tr.done_t > fab.clock.now()    # genuinely in flight
    fab.fail_host(owner)
    assert not pf.done()
    np.testing.assert_array_equal(pf.wait(), val)


def test_inflight_remote_fetch_of_sole_copy_raises():
    fab, key, val, pf, owner = _remote_setup(replicas=1)
    fab.fail_host(owner)
    with pytest.raises(KeyError):
        pf.wait()


# ---------------------------------------------------------------------------
# repair loop: restores the declared degree, paced by rebalance_rate
# ---------------------------------------------------------------------------

def test_repair_restores_replication_degree():
    fab = _fabric(4)
    keys = []
    for i in range(60):
        key = ("kv", i)
        fab.put(key, np.full(256, i, np.int32), tier=Tier.FLASH,
                from_host=fab.owner(key), replicas=2)
        keys.append(key)
    fab.drain()
    victim = fab.host_ids[1]
    fab.fail_host(victim)
    loop = RepairLoop(fab)
    assert loop.pending(), "a failure must leave under-replicated keys"
    stats = loop.run()
    assert stats.keys_repaired > 0 and stats.bytes_repaired > 0
    assert stats.t_done >= stats.t_start
    assert not fab.under_replicated()
    fab.drain()
    for i, key in enumerate(keys):
        holders = fab.holders(key)
        assert len(holders) == 2
        assert holders == fab._targets(key)
        np.testing.assert_array_equal(
            fab.get(key, from_host=fab.host_ids[0]),
            np.full(256, i, np.int32))


def test_repair_is_paced_by_rebalance_rate():
    """A slower token bucket must produce a strictly later repair
    horizon for the same repair work."""
    def recovery(rate):
        fab = _fabric(3, rebalance_rate=rate)
        for i in range(30):
            key = ("kv", i)
            fab.put(key, np.zeros(1 << 12, np.uint8), tier=Tier.FLASH,
                    from_host=fab.owner(key), replicas=2)
        fab.drain()
        report = fab.fail_host(fab.host_ids[0])
        stats = RepairLoop(fab).run()
        assert not fab.under_replicated()
        return stats.t_done - report.t_fail

    slow, fast = recovery(1e6), recovery(1e9)
    assert slow > fast, (slow, fast)
    # the slow-arm floor: total repaired bytes cannot stream faster
    # than the bucket refills (split across at most 2 sources)
    assert slow > (30 // 2) * (1 << 12) / 1e6 / 2


def test_repair_step_is_bounded():
    fab = _fabric(3)
    for i in range(20):
        key = ("kv", i)
        fab.put(key, np.zeros(128, np.uint8), tier=Tier.FLASH,
                from_host=fab.owner(key), replicas=2)
    fab.drain()
    fab.fail_host(fab.host_ids[0])
    loop = RepairLoop(fab, batch_keys=4)
    pending0 = len(loop.pending())
    stats = loop.step()
    assert stats.keys_scanned <= 4
    assert len(loop.pending()) < pending0


# ---------------------------------------------------------------------------
# property: random interleavings never lose a replicated key
# ---------------------------------------------------------------------------

MAX_HOSTS = 6


def _apply_failure_ops(ops):
    """Drive put/get/add_host/fail_host/repair while mirroring a dict
    model. `fail_host` may only lose keys that were already down to a
    single copy (a prior failure, not yet repaired); those leave the
    model via the FailureReport."""
    fab = _fabric(3)
    loop = RepairLoop(fab)
    model = {}
    for code, arg in ops:
        if code in (0, 1):
            key = ("k", arg % 20)
            val = np.full(64, arg, np.int32)
            fab.put(key, val, tier=Tier.FLASH,
                    from_host=fab.host_ids[arg % fab.n_hosts],
                    replicas=2)
            model[key] = val
        elif code == 2 and model:
            key = list(model)[arg % len(model)]
            got = fab.get(key, from_host=fab.host_ids[arg % fab.n_hosts])
            np.testing.assert_array_equal(got, model[key])
        elif code == 3 and fab.n_hosts < MAX_HOSTS:
            fab.add_host()
        elif code == 4 and fab.n_hosts > 1:
            victim = fab.host_ids[arg % fab.n_hosts]
            at_risk = {k for k in model if len(fab.holders(k)) <= 1}
            report = fab.fail_host(victim)
            lost = set(report.lost_keys)
            # a key with >= 2 live copies is NEVER lost
            assert lost <= at_risk, (lost, at_risk)
            for key in lost:
                model.pop(key, None)
        elif code == 5:
            loop.run()
    return fab, loop, model


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=5),
                          st.integers(min_value=0, max_value=1000)),
                min_size=1, max_size=24))
def test_failure_interleavings_never_lose_replicated_keys(ops):
    fab, loop, model = _apply_failure_ops(ops)
    # repair converges: every surviving key back at declared degree
    loop.run()
    fab.drain()
    assert not fab.under_replicated()
    for key, val in model.items():
        holders = fab.holders(key)
        want = min(2, fab.n_hosts)
        assert len(holders) == want, (key, holders)
        assert holders == fab._targets(key)
        got = fab.get(key, from_host=fab.host_ids[0])
        np.testing.assert_array_equal(got, val)
    live = {k for s in fab.hosts.values() for k in s.keys()}
    assert live == set(model)


# ---------------------------------------------------------------------------
# ghost-cache hygiene across key loss (ReuseTracker / gates)
# ---------------------------------------------------------------------------

def test_reuse_tracker_forget_keys_purges_ghost_only():
    tr = ReuseTracker()
    tr.observe("a", "kv", 1.0)
    tr.observe("a", "kv", 2.0)          # measured interval -> sketch
    mass = tr.class_mass("kv")
    assert mass > 0 and tr.last_seen("a") == 2.0
    tr.forget_keys(["a", "never-seen"])
    assert tr.last_seen("a") is None
    # class history survives: the *class* evidence is still valid
    assert tr.class_mass("kv") == mass


def test_ghost_evicts_in_oldest_last_seen_order():
    """Regression lock: re-touching a key must move it to the back of
    the ghost's eviction order (true last-seen order, not insertion
    order)."""
    tr = ReuseTracker(ghost_capacity=3)
    tr.observe("a", "kv", 1.0)
    tr.observe("b", "kv", 2.0)
    tr.observe("c", "kv", 3.0)
    tr.observe("a", "kv", 4.0)          # re-touch: a is now newest
    tr.observe("d", "kv", 5.0)          # capacity 3: evicts oldest
    assert tr.last_seen("b") is None, "b (oldest last-seen) must go"
    assert tr.last_seen("a") == 4.0
    assert tr.last_seen("c") == 3.0 and tr.last_seen("d") == 5.0


def test_key_loss_resets_admission_evidence():
    """A key wiped by a failure must be priced as a first touch when it
    comes back — not re-admitted on its dead predecessor's ghost gap."""
    clock = VirtualClock()
    tracker = ReuseTracker()

    def gates(_h):
        return EconomicGate(tau_hot=1e-6, tau_be=5.0, tracker=tracker)

    fab = ShardedTieredStore(3, policy_factory=gates, clock=clock)
    key = ("kv", "sess")
    owner = fab.owner(key)
    fab.put(key, np.zeros(256, np.float32), from_host=owner)
    clock.advance(1.0)
    fab.get(key, from_host=owner)       # ghost now has a measured touch
    assert tracker.last_seen(key) is not None
    fab.fail_host(owner)
    assert tracker.last_seen(key) is None, \
        "failure must purge the ghost entry"
    readmits_before = sum(
        s.policy.gate_stats.readmits_measured for s in fab.hosts.values())
    clock.advance(0.5)
    fab.put(key, np.zeros(256, np.float32), from_host=fab.host_ids[0])
    readmits_after = sum(
        s.policy.gate_stats.readmits_measured for s in fab.hosts.values())
    assert readmits_after == readmits_before, \
        "re-put after loss must not count as a measured re-admission"


def test_delete_also_purges_ghost():
    clock = VirtualClock()
    tracker = ReuseTracker()

    def gates(_h):
        return EconomicGate(tau_hot=1e-6, tau_be=5.0, tracker=tracker)

    fab = ShardedTieredStore(2, policy_factory=gates, clock=clock)
    key = ("kv", "gone")
    fab.put(key, np.zeros(64, np.float32), from_host=fab.owner(key))
    assert tracker.last_seen(key) is not None
    fab.delete(key)
    assert tracker.last_seen(key) is None


def test_tiering_policy_forget_keys_base():
    pol = TieringPolicy(tau_hot=0.1, tau_be=5.0)
    pol.observe("a", now=1.0)
    pol.observe("a", now=2.0)
    assert "a" in pol._ema and "a" in pol._tier
    pol.forget_keys(["a"])
    assert "a" not in pol._ema and "a" not in pol._last_seen \
        and "a" not in pol._tier

# ---------------------------------------------------------------------------
# engine checkpointing + torn-session export (gemma-2b reduced fixture)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rules, params


def _reference_generate(cfg, rules, params, prompt, n_new):
    import jax.numpy as jnp
    from repro.models import model as M
    cache = M.init_cache(cfg, 1, 64, dtype=jnp.float32)
    cache, logits = M.prefill(params, cfg, rules,
                              {"tokens": jnp.asarray(prompt[None])},
                              cache, compute_dtype=jnp.float32)
    out = [int(np.argmax(np.asarray(logits[0])))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        cache, logits = M.decode_step(
            params, cfg, rules, jnp.asarray([[out[-1]]]), cache,
            jnp.asarray(pos, jnp.int32), compute_dtype=jnp.float32)
        out.append(int(np.argmax(np.asarray(logits[0]))))
        pos += 1
    return out


def test_checkpointed_session_survives_host_failure(setup):
    """The tentpole, end to end: periodic checkpoints + replicated KV
    -> after an unplanned failure of the serving host, a surviving
    engine resumes from the last checkpoint and greedy decode
    regenerates exactly the reference tokens."""
    from repro.serving.engine import DecodeEngine, Request
    cfg, rules, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab, 6).astype(np.int32)
    ref = _reference_generate(cfg, rules, params, prompt, 10)

    fab = _fabric(2)
    eng_a = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                         store=fab.host_view(fab.host_ids[0], replicas=2),
                         checkpoint_interval=2)
    eng_b = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                         store=fab.host_view(fab.host_ids[1], replicas=2))
    req = Request(rid="s", prompt=prompt, max_new=10)
    eng_a.admit(req)
    for _ in range(5):
        eng_a.step()                    # checkpoints at steps 2 and 4
    ckpts = eng_a.checkpoints()
    assert "s" in ckpts
    n_at_ckpt = len(ckpts["s"][0].generated)
    assert n_at_ckpt == 5               # admit token + 4 steps

    fab.fail_host(eng_a.host)           # the serving host dies, no drain
    assert fab.holders(("kv", "s")), "replicated checkpoint must survive"
    slot = eng_b.restore_checkpoint("s", ckpts["s"])
    resumed = eng_b.slot_req[slot]
    while not resumed.done:
        eng_b.step()
    assert resumed.generated == ref, (resumed.generated, ref)


def test_export_session_refuses_torn_session(setup):
    """Metadata must never outlive the KV blob: exporting a session
    whose only copy died raises, and the session stays importable-free
    (restartable) rather than half-exported."""
    from repro.serving.engine import DecodeEngine, Request
    cfg, rules, params = setup
    rng = np.random.default_rng(8)
    prompt = rng.integers(1, cfg.vocab, 5).astype(np.int32)

    fab = _fabric(2)
    eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                       store=fab.host_view(fab.host_ids[0]))
    req = Request(rid="t", prompt=prompt, max_new=8)
    eng.admit(req)
    for _ in range(2):
        eng.step()
    eng.pause("t")
    holder = fab.holders(("kv", "t"))[0]    # replicas=1: sole copy
    fab.fail_host(holder)
    with pytest.raises(KeyError, match="torn"):
        eng.export_session("t")
    assert "t" in eng._paused, "failed export must not drop the state"


def test_export_mid_flight_session_waits_delivery_horizon(setup):
    """A session whose KV blob is still streaming (NIC in flight to a
    remote holder) exports safely: the placement is structural, and the
    importing engine's resume pays the arrival gate instead of reading
    torn bytes."""
    from repro.serving.engine import DecodeEngine, Request
    cfg, rules, params = setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab, 6).astype(np.int32)
    ref = _reference_generate(cfg, rules, params, prompt, 8)

    fab = _fabric(2)
    engines = {h: DecodeEngine(cfg, params, rules, max_slots=2,
                               max_len=64,
                               store=fab.host_view(h, replicas=2))
               for h in fab.host_ids}
    src = engines[fab.host_ids[0]]
    dst = engines[fab.host_ids[1]]
    req = Request(rid="m", prompt=prompt, max_new=8)
    src.admit(req)
    for _ in range(3):
        src.step()
    src.pause("m")                      # remote copy still on the wire
    state = src.export_session("m")     # must not tear
    dst.import_session("m", state)
    dst.resume("m")
    while not req.done:
        dst.step()
    assert req.generated == ref, (req.generated, ref)


def test_engine_periodic_checkpoint_clears_on_done(setup):
    from repro.serving.engine import DecodeEngine, Request
    cfg, rules, params = setup
    rng = np.random.default_rng(10)
    prompt = rng.integers(1, cfg.vocab, 4).astype(np.int32)
    eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                       checkpoint_interval=2)
    req = Request(rid="c", prompt=prompt, max_new=6)
    eng.admit(req)
    eng.step(); eng.step()
    assert "c" in eng.checkpoints()
    while not req.done:
        eng.step()
    assert "c" not in eng.checkpoints(), \
        "a finished request must not leave checkpoint state behind"


# ---------------------------------------------------------------------------
# spec + platform wiring
# ---------------------------------------------------------------------------

def test_spec_validates_mttf_and_checkpoint_interval():
    from repro.platform import HierarchySpec
    with pytest.raises(ValueError, match="mttf"):
        HierarchySpec(mttf=-5.0).validate()
    with pytest.raises(ValueError, match="checkpoint_interval"):
        HierarchySpec(checkpoint_interval=0.0).validate()
    spec = HierarchySpec(replicas=2, mttf=3600.0,
                         checkpoint_interval=2.0).validate()
    rt = type(spec).from_json(spec.to_json())
    assert rt == spec
    assert rt.mttf == 3600.0 and rt.checkpoint_interval == 2.0


def test_platform_engine_honors_replicas_and_checkpoints(setup):
    """Regression: `Platform.engine` used to hand the engine a
    host view *without* the spec's replication factor, so paused /
    checkpointed KV silently ran unreplicated."""
    from repro.platform import (HierarchySpec, HostDecl, Platform,
                                PolicyDecl)
    cfg, rules, params = setup
    spec = HierarchySpec(
        hosts=(HostDecl(count=2),),
        policy=PolicyDecl.static(tau_hot=1e-12, tau_be=1e9),
        replicas=2, step_time=0.25, checkpoint_interval=1.0,
        mttf=7200.0)
    platform = Platform.compile(spec)
    eng = platform.engine(cfg, params, rules, host=0)
    assert eng.store.replicas == 2
    assert eng.checkpoint_interval == 4     # 1.0s / 0.25s per step
    assert platform.checkpoint_steps() == 4


def test_platform_fail_and_repair_capabilities():
    from repro.platform import HierarchySpec, HostDecl, Platform, \
        PolicyDecl
    spec = HierarchySpec(
        hosts=(HostDecl(count=3),),
        policy=PolicyDecl.static(tau_hot=1e-12, tau_be=1e-9),
        replicas=2)
    platform = Platform.compile(spec)
    fab = platform.fabric
    for i in range(12):
        key = ("kv", i)
        fab.put(key, np.zeros(128, np.uint8), tier=Tier.FLASH,
                from_host=fab.owner(key), replicas=2)
    fab.drain()
    report = platform.fail_host(fab.host_ids[0])
    assert report.keys_lost == 0
    stats = platform.repair()
    assert stats.keys_repaired > 0
    assert not fab.under_replicated()
    # availability pricing needs the economic policy
    with pytest.raises(ValueError, match="advisor"):
        platform.advise_availability(mttf=100.0)


# ---------------------------------------------------------------------------
# availability pricing (advisor) + the kill-at-peak benchmark
# ---------------------------------------------------------------------------

def _advisor():
    from repro.autopilot.advisor import ProvisionAdvisor
    from repro.core.economics import GPU_GDDR
    from repro.core.ssd_model import storage_next_ssd
    return ProvisionAdvisor(GPU_GDDR, storage_next_ssd(), 128 << 10)


def test_advise_availability_mttf_shapes_the_recommendation():
    adv = _advisor()
    resident = 64 << 20
    stable = adv.advise_availability(resident_bytes=resident, n_hosts=4,
                                     dram_fraction=0.35, mttf=1e12)
    assert stable.recommended_replicas == 1
    assert stable.arms[1]["loss"] < stable.arms[2]["total"]
    flaky = adv.advise_availability(resident_bytes=resident, n_hosts=4,
                                    dram_fraction=0.35, mttf=600.0)
    assert flaky.recommended_replicas >= 2
    assert flaky.arms[1]["loss"] > flaky.arms[1]["rent"]
    assert set(flaky.arms) == {1, 2, 3}
    # copy costs rise monotonically with r
    assert flaky.arms[3]["rent"] > flaky.arms[2]["rent"]
    d = flaky.as_dict()
    assert set(d["arms"]) == {"1", "2", "3"}
    assert "VERDICT" in flaky.report()


def test_advise_availability_from_live_fabric():
    adv = _advisor()
    fab = _fabric(3)
    for i in range(10):
        key = ("kv", i)
        fab.put(key, np.zeros(1 << 16, np.uint8), tier=Tier.FLASH,
                from_host=fab.owner(key), replicas=2)
    fab.drain()
    advice = adv.advise_availability(fabric=fab, mttf=300.0)
    assert advice.n_hosts == 3
    assert advice.resident_bytes == 10 * (1 << 16)   # unique payload
    with pytest.raises(ValueError, match="mttf"):
        adv.advise_availability(resident_bytes=1.0, n_hosts=2, mttf=0.0)
    with pytest.raises(ValueError):
        adv.advise_availability(mttf=100.0)          # no census source


def test_failover_bench_acceptance_and_determinism():
    """The PR's acceptance criterion, asserted: with replicas>=2 and
    checkpointing on, zero committed keys lost and every session
    resumes; the advisor's recommended replication factor beats both
    r=1 and r=3 on measured $/token; byte-deterministic double run."""
    from repro.platform import run_failover_bench
    kw = dict(n_steps=100, n_sessions=8)
    out = run_failover_bench(**kw)
    assert out["zero_committed_loss_replicated"]
    assert out["all_sessions_resume_replicated"]
    rec = int(out["recommended_replicas"])
    assert rec == 2
    assert out["recommended_wins"]
    cpt = {r: arm["cost_per_token"] for r, arm in out["arms"].items()}
    assert cpt[str(rec)] < cpt["1"] and cpt[str(rec)] < cpt["3"]
    # unreplicated really does lose data at the kill (the bench bites)
    assert out["arms"]["1"]["committed_keys_lost"] > 0
    assert out["arms"]["2"]["recovery_seconds"] > 0
    # byte-identical across in-process double runs
    again = run_failover_bench(**kw)
    assert json.dumps(out, sort_keys=True) == \
        json.dumps(again, sort_keys=True)
