"""Core analytics vs the paper's own published numbers (§III-§V)."""
import dataclasses
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CPU_DDR, GPU_GDDR, LatencyTargets, LogNormalWorkload, EmpiricalWorkload,
    break_even, bottleneck, gamma_from_mix, iops_ssd_peak, normal_ssd,
    rho_max_for_targets, storage_next_ssd, thresholds, usable_iops,
)
from repro.core.constraints import tail_read_latency, mean_read_latency
from repro.core.economics import break_even_components
from repro.core.ssd_model import PSLC, TLC, rw_fractions


SSD = storage_next_ssd()


# ---------------------------------------------------------------------------
# §III-B / Table II: first-principles IOPS
# ---------------------------------------------------------------------------

class TestSsdModel:
    def test_paper_headline_iops(self):
        # "IOPS_SSD ~= 57M at 512B and ~= 11M at 4KB"
        assert float(iops_ssd_peak(SSD, 512)) == pytest.approx(57.4e6, rel=0.01)
        assert float(iops_ssd_peak(SSD, 4096)) == pytest.approx(11.1e6, rel=0.01)

    @pytest.mark.parametrize("n_ch,n_nand,tau_cmd,at512,at4k", [
        (16, 3, 200e-9, 39.4e6, 8.5e6),    # Table II pessimistic
        (20, 4, 150e-9, 57.4e6, 11.1e6),   # baseline
        (24, 5, 100e-9, 79.3e6, 13.8e6),   # optimistic
    ])
    def test_table2_sensitivity(self, n_ch, n_nand, tau_cmd, at512, at4k):
        cfg = dataclasses.replace(SSD, n_ch=n_ch, n_nand=n_nand,
                                  tau_cmd=tau_cmd)
        assert float(iops_ssd_peak(cfg, 512)) == pytest.approx(at512, rel=0.01)
        assert float(iops_ssd_peak(cfg, 4096)) == pytest.approx(at4k, rel=0.01)

    def test_iops_monotone_in_block_size(self):
        vals = [float(iops_ssd_peak(SSD, l)) for l in (512, 1024, 2048, 4096)]
        assert vals == sorted(vals, reverse=True)

    def test_nand_ordering(self):
        # SLC > pSLC > TLC at every block size (Fig. 3)
        for l in (512, 1024, 2048, 4096):
            slc = float(iops_ssd_peak(SSD, l))
            pslc = float(iops_ssd_peak(storage_next_ssd(PSLC), l))
            tlc = float(iops_ssd_peak(storage_next_ssd(TLC), l))
            assert slc > pslc > tlc

    def test_tlc_device_limited_flat(self):
        # TLC: long sense/program keeps the die the limiter at all sizes,
        # so IOPS varies only weakly with block size (Fig. 3 discussion).
        tlc = storage_next_ssd(TLC)
        v512 = float(iops_ssd_peak(tlc, 512))
        v4k = float(iops_ssd_peak(tlc, 4096))
        assert bottleneck(tlc, 512) == "nand_die"
        assert v512 / v4k < 1.6      # near-flat vs SLC's ~5.2x

    def test_normal_ssd_flat_below_4k(self):
        # 4KB-oriented ECC: sub-4KB requests cost a full codeword.
        nr = normal_ssd()
        assert float(iops_ssd_peak(nr, 512)) == pytest.approx(
            float(iops_ssd_peak(nr, 4096)), rel=1e-6)

    def test_read_only_exceeds_mixed(self):
        ro = float(iops_ssd_peak(SSD, 512, gamma_rw=float("inf")))
        mixed = float(iops_ssd_peak(SSD, 512, gamma_rw=9.0))
        heavy = float(iops_ssd_peak(SSD, 512, gamma_rw=1.0))
        assert ro > mixed > heavy

    def test_rw_fractions_sum(self):
        r, w, hf = rw_fractions(9.0, 3.0)
        assert float(r) + float(w) == pytest.approx(1.0)
        assert 0 < float(hf) <= 1.0
        r, w, hf = rw_fractions(float("inf"), 3.0)
        assert (float(r), float(w), float(hf)) == (1.0, 0.0, 1.0)

    def test_gamma_from_mix(self):
        assert gamma_from_mix(90, 10) == 9.0
        assert gamma_from_mix(100, 0) == float("inf")

    def test_cost_structure(self):
        # 20ch x 4 dies + ctrl 15 + ceil(40GB ftl / 3GB) DRAM dies
        assert SSD.n_s_dram == 14
        assert SSD.cost == pytest.approx(15 + 80 + 14)


# ---------------------------------------------------------------------------
# §III-C / Fig. 4: calibrated break-even
# ---------------------------------------------------------------------------

class TestEconomics:
    def test_fig4_cpu_anchors(self):
        # "~34s at 512B ... ~10s at 4KB" (CPU+DDR, SLC, Storage-Next)
        be512 = float(break_even(CPU_DDR, 512, SSD.cost,
                                 iops_ssd_peak(SSD, 512)))
        be4k = float(break_even(CPU_DDR, 4096, SSD.cost,
                                iops_ssd_peak(SSD, 4096)))
        assert be512 == pytest.approx(34.0, rel=0.1)
        assert be4k == pytest.approx(10.0, rel=0.15)

    def test_fig4_gpu_anchor_and_7x(self):
        cpu = float(break_even(CPU_DDR, 512, SSD.cost,
                               iops_ssd_peak(SSD, 512)))
        gpu = float(break_even(GPU_GDDR, 512, SSD.cost,
                               iops_ssd_peak(SSD, 512)))
        assert gpu == pytest.approx(5.0, rel=0.1)
        assert cpu / gpu == pytest.approx(7.0, rel=0.1)

    def test_seconds_not_minutes(self):
        # the paper's headline: thresholds collapse below the minute scale
        for host in (CPU_DDR, GPU_GDDR):
            for l in (512, 1024, 2048, 4096):
                be = float(break_even(host, l, SSD.cost,
                                      iops_ssd_peak(SSD, l)))
                assert be < 60.0

    def test_components_positive_and_sum(self):
        comps = break_even_components(CPU_DDR, 512, SSD.cost,
                                      iops_ssd_peak(SSD, 512))
        total = float(break_even(CPU_DDR, 512, SSD.cost,
                                 iops_ssd_peak(SSD, 512)))
        assert all(float(v) > 0 for v in comps.values())
        assert float(sum(comps.values())) == pytest.approx(total)

    def test_fig5a_host_budget_anchors(self):
        # CPU 512B: budget 40M -> ~83s, 100M -> ~47s (4 SSDs)
        peak = float(iops_ssd_peak(SSD, 512))
        for budget, expect in ((40e6, 83.0), (100e6, 47.0)):
            per = float(usable_iops(peak, 1.0, budget, 4))
            be = float(break_even(CPU_DDR, 512, SSD.cost, per))
            assert be == pytest.approx(expect, rel=0.1)

    def test_storage_next_beats_normal_small_blocks(self):
        for l in (512, 1024, 2048):
            sn = float(break_even(CPU_DDR, l, SSD.cost, iops_ssd_peak(SSD, l)))
            nr_ssd = normal_ssd()
            nr = float(break_even(CPU_DDR, l, nr_ssd.cost,
                                  iops_ssd_peak(nr_ssd, l)))
            assert sn < nr


# ---------------------------------------------------------------------------
# §IV / Table IV: M/D/1 constraints
# ---------------------------------------------------------------------------

class TestConstraints:
    @pytest.mark.parametrize("l_blk,tail_us,rho", [
        (512, 7, 0.70), (512, 9, 0.80), (512, 13, 0.90), (512, 85, 0.99),
        (4096, 16, 0.70), (4096, 44, 0.90), (4096, 418, 0.99),
    ])
    def test_table4_tiers(self, l_blk, tail_us, rho):
        peak = float(iops_ssd_peak(SSD, l_blk))
        got = float(rho_max_for_targets(
            LatencyTargets(tail=tail_us * 1e-6), SSD.n_ch, peak,
            SSD.nand.tau_sense))
        assert got == pytest.approx(rho, abs=0.05)

    def test_rho_roundtrip(self):
        # latency at rho_max equals the target (closed-form inverse)
        peak = float(iops_ssd_peak(SSD, 512))
        t = 13e-6
        rho = float(rho_max_for_targets(LatencyTargets(tail=t), SSD.n_ch,
                                        peak, SSD.nand.tau_sense))
        back = float(tail_read_latency(rho, SSD.n_ch, peak,
                                       SSD.nand.tau_sense, p=0.99))
        assert back == pytest.approx(t, rel=1e-6)

    def test_mean_constraint(self):
        peak = float(iops_ssd_peak(SSD, 512))
        rho = float(rho_max_for_targets(LatencyTargets(mean=6e-6), SSD.n_ch,
                                        peak, SSD.nand.tau_sense))
        back = float(mean_read_latency(rho, SSD.n_ch, peak,
                                       SSD.nand.tau_sense))
        assert back == pytest.approx(6e-6, rel=1e-6)

    def test_impossible_target_zero(self):
        peak = float(iops_ssd_peak(SSD, 512))
        rho = float(rho_max_for_targets(
            LatencyTargets(tail=1e-6),  # below tau_sense
            SSD.n_ch, peak, SSD.nand.tau_sense))
        assert rho == 0.0

    @given(st.floats(min_value=5.5e-6, max_value=1e-3),
           st.floats(min_value=5.5e-6, max_value=1e-3))
    @settings(max_examples=50, deadline=None)
    def test_rho_monotone_in_target(self, t1, t2):
        peak = float(iops_ssd_peak(SSD, 512))
        r1 = float(rho_max_for_targets(LatencyTargets(tail=t1), SSD.n_ch,
                                       peak, SSD.nand.tau_sense))
        r2 = float(rho_max_for_targets(LatencyTargets(tail=t2), SSD.n_ch,
                                       peak, SSD.nand.tau_sense))
        if t1 <= t2:
            assert r1 <= r2 + 1e-12
        else:
            assert r2 <= r1 + 1e-12

    def test_usable_iops_host_cap(self):
        assert float(usable_iops(57e6, 0.9, 100e6, 4)) == pytest.approx(25e6)
        assert float(usable_iops(10e6, 0.9, 100e6, 4)) == pytest.approx(9e6)


# ---------------------------------------------------------------------------
# §V: workload thresholds
# ---------------------------------------------------------------------------

class TestWorkload:
    def _wl(self, sigma=1.0, l_blk=512):
        # §V-B: 1e9 blocks, 200 GB/s total throughput
        return LogNormalWorkload.from_total_throughput(
            200e9, sigma=sigma, n_blk=1e9, l_blk=l_blk)

    def test_total_throughput_pinned(self):
        wl = self._wl()
        assert wl.total_throughput == pytest.approx(200e9, rel=1e-9)

    def test_psi_split_conserves(self):
        wl = self._wl()
        for T in (0.01, 0.1, 1.0, 10.0, 100.0):
            assert float(wl.psi_c(T) + wl.psi_d(T)) == pytest.approx(
                wl.total_throughput, rel=1e-9)

    def test_bw_use_decreasing(self):
        wl = self._wl()
        ts = np.logspace(-3, 3, 25)
        bws = [float(wl.dram_bw_use(t)) for t in ts]
        assert all(b1 >= b2 - 1e-3 for b1, b2 in zip(bws, bws[1:]))

    def test_threshold_inversions_roundtrip(self):
        wl = self._wl()
        # B >= 2*Theta: constraint holds for any T -> T_B = 0
        assert wl.bandwidth_threshold(540e9) == 0.0
        # Theta < B < 2*Theta: tight crossing
        t_b = wl.bandwidth_threshold(250e9)
        assert float(wl.dram_bw_use(t_b)) == pytest.approx(250e9, rel=1e-6)
        t_s = wl.ssd_threshold(50e9)
        assert float(wl.psi_d(t_s)) == pytest.approx(50e9, rel=1e-6)
        t_c = wl.capacity_threshold(64e9)
        assert float(wl.cached_bytes(t_c)) == pytest.approx(64e9, rel=1e-6)

    def test_infeasible_bandwidth(self):
        wl = self._wl()
        assert wl.bandwidth_threshold(100e9) == float("inf")  # < Theta

    def test_hit_rate_saturates(self):
        wl = self._wl()
        assert float(wl.hit_rate_for_capacity(0)) == 0.0
        assert float(wl.hit_rate_for_capacity(wl.total_bytes)) == 1.0
        mid = float(wl.hit_rate_for_capacity(wl.total_bytes / 2))
        assert 0.5 < mid < 1.0   # hot half carries > half the accesses

    @given(st.floats(min_value=0.3, max_value=2.0),
           st.integers(min_value=200, max_value=2000))
    @settings(max_examples=20, deadline=None)
    def test_lognormal_matches_empirical(self, sigma, n):
        """Closed forms agree with a sampled empirical profile."""
        wl = LogNormalWorkload.from_total_throughput(
            1e9, sigma=sigma, n_blk=float(n), l_blk=512)
        emp = EmpiricalWorkload(wl.sample_intervals(n, seed=7), 512)
        T = float(np.exp(wl.mu))  # median
        assert float(emp.cached_block_fraction(T)) == pytest.approx(
            float(wl.cached_block_fraction(T)), abs=0.1)
        assert float(emp.psi_c(T)) == pytest.approx(
            float(wl.psi_c(T)), rel=0.5)

    def test_empirical_threshold_semantics(self):
        emp = EmpiricalWorkload([1.0, 2.0, 4.0, 8.0], l_blk=1024)
        # Caching the two hottest blocks leaves psi_d = 1024*(1/4+1/8)
        t_s = emp.ssd_threshold(1024 * (1 / 4 + 1 / 8))
        assert t_s == pytest.approx(2.0)
        assert emp.capacity_threshold(2 * 1024) == pytest.approx(2.0)
        assert emp.capacity_threshold(100 * 1024) == float("inf")

    def test_thresholds_report(self):
        wl = self._wl()
        th = thresholds(wl, b_dram=540e9, b_ssd=4 * 512 * 25e6,
                        c_dram=256e9)
        assert th.t_v == max(th.t_b, th.t_s)
        assert isinstance(th.viable, bool)
