"""Case-study tests: cuckoo invariants (hypothesis), WAL semantics,
two-stage ANN recall, and the Fig. 8 / Fig. 10 model anchors."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kvstore.cuckoo import BlockedCuckooStore
from repro.kvstore.model import (KvWorkload, achievable_throughput,
                                 cpu_sn_platform, gpu_nr_platform,
                                 gpu_sn_platform)
from repro.ann.corpus import make_corpus, make_queries
from repro.ann.model import AnnWorkload, gpu_nr, gpu_sn, throughput_kqps
from repro.ann.progressive import exact_topk, recall_at_k, search


# ---------------------------------------------------------------------------
# cuckoo store
# ---------------------------------------------------------------------------

def test_cuckoo_basic_roundtrip():
    st_ = BlockedCuckooStore(1024, slots=8, wal_limit=32)
    for k in range(1, 2000):
        st_.put(k, k * 3)
    st_.flush()
    for k in (1, 500, 1999):
        assert st_.get(k) == k * 3
    assert st_.get(123456) is None


def test_cuckoo_wal_visibility_and_coalescing():
    st_ = BlockedCuckooStore(256, slots=8, wal_limit=1000)
    st_.put(42, 1)
    assert st_.get(42) == 1            # visible pre-flush via WAL
    st_.put(42, 2)
    st_.put(42, 3)
    st_.flush()
    assert st_.get(42) == 3            # last write wins
    # 3 appends to the same key = 1 insert (coalesced)
    assert st_.stats.inserts == 1


def test_cuckoo_update_in_place():
    st_ = BlockedCuckooStore(256, slots=8, wal_limit=1)
    st_.put(7, 10)
    st_.put(7, 20)
    assert st_.get(7) == 20
    assert st_.stats.updates >= 1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), load=st.floats(0.3, 0.85))
def test_cuckoo_property_all_inserted_retrievable(seed, load):
    nb, slots = 512, 8
    s = BlockedCuckooStore(nb, slots=slots, wal_limit=64, seed=seed)
    rng = np.random.default_rng(seed)
    n = int(nb * slots * load)
    keys = rng.choice(np.arange(1, 10**7), size=n, replace=False)
    for k in keys:
        s.put(int(k), int(k) % 7919)
    s.flush()
    assert abs(s.load_factor() - load) < 0.02
    probe = keys[rng.integers(0, n, min(n, 300))]
    for k in probe:
        assert s.get(int(k)) == int(k) % 7919
    # kernel path agrees
    f, v = s.get_batch(probe.astype(np.int32), use_kernel=True)
    assert f.all()
    assert (v == probe % 7919).all()


def test_cuckoo_survives_high_load():
    """Paper: alpha_critical > 0.95 for B >= 4; we fill to 0.90."""
    nb, slots = 256, 8
    s = BlockedCuckooStore(nb, slots=slots, wal_limit=64, seed=3)
    rng = np.random.default_rng(3)
    keys = rng.choice(np.arange(1, 10**7), size=int(nb * slots * 0.9),
                      replace=False)
    for k in keys:
        s.put(int(k), 1)
    s.flush()
    assert s.load_factor() >= 0.89
    assert s.stats.failed_inserts == 0


def test_fig8_model_anchors():
    wl = KvWorkload(get_frac=0.9, sigma=1.2)
    g = achievable_throughput(gpu_sn_platform(), wl, 256e9)
    c = achievable_throughput(cpu_sn_platform(), wl, 256e9)
    n = achievable_throughput(gpu_nr_platform(), wl, 256e9)
    assert g["throughput"] > 100e6           # in-memory-class
    assert c["limiter"] == "host-iops"       # CPU host-bound
    assert n["throughput"] < g["throughput"] / 3   # normal SSD far below
    # locality ordering
    weak = achievable_throughput(gpu_sn_platform(),
                                 KvWorkload(get_frac=0.9, sigma=0.4),
                                 256e9)
    assert weak["throughput"] < g["throughput"]
    # write share hurts
    w50 = achievable_throughput(gpu_sn_platform(),
                                KvWorkload(get_frac=0.5, sigma=1.2),
                                256e9)
    assert w50["throughput"] < g["throughput"]


# ---------------------------------------------------------------------------
# two-stage ANN
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus():
    full, red, _ = make_corpus(8000, 1024, 128)
    qs = make_queries(full, 100)
    return full, red, qs


def test_ann_recall_above_98(corpus):
    full, red, qs = corpus
    truth = exact_topk(qs, full, 10)
    pred, stats = search(qs, red, full, k=10, promote=64)
    assert recall_at_k(pred, truth) > 0.98
    # promoted set is a small fraction (paper: most comparisons reject)
    assert stats.stage2_reads / stats.stage1_reads < 0.02


def test_ann_recall_grows_with_promotion(corpus):
    full, red, qs = corpus
    truth = exact_topk(qs, full, 10)
    r = []
    for promote in (16, 64):
        pred, _ = search(qs, red, full, k=10, promote=promote,
                         use_kernel=False)
        r.append(recall_at_k(pred, truth))
    assert r[1] >= r[0]


def test_fig10_model_anchors():
    wl = AnnWorkload()
    a = [throughput_kqps(gpu_sn(), wl, d)["kqps"]
         for d in (64e9, 256e9, 512e9)]
    assert a[0] < a[1] < a[2]                 # caching helps
    nr = throughput_kqps(gpu_nr(), wl, 256e9)["kqps"]
    assert a[1] / nr > 2.0                    # SN >= 2-3x normal
    assert 5 < a[2] < 30                      # paper's 13-17 KQPS regime
