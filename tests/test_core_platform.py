"""Platform advisor (paper §V-B) and TieringPolicy behaviour."""
import pytest

from repro.core import (
    CPU_PLATFORM, GPU_PLATFORM, LatencyTargets, LogNormalWorkload,
    Tier, TieringPolicy, analyze_platform,
)


def _wl(l_blk=512):
    return LogNormalWorkload.from_total_throughput(
        200e9, sigma=1.0, n_blk=1e9, l_blk=l_blk)


class TestAdvisor:
    def test_gpu_needs_less_viable_dram(self):
        """§V-B: GPU+Storage-Next achieves viability with far less DRAM."""
        targets = LatencyTargets(tail=13e-6)   # rho_max ~ 0.9 tier
        cpu = analyze_platform(CPU_PLATFORM, _wl(), 512, targets)
        gpu = analyze_platform(GPU_PLATFORM, _wl(), 512, targets)
        assert gpu.c_dram_viable < cpu.c_dram_viable
        assert gpu.tau_break_even < cpu.tau_break_even

    def test_cpu_host_limited_at_512(self):
        """CPU budget 100M/4 SSDs = 25M < rho*57M: host is the cap."""
        rep = analyze_platform(CPU_PLATFORM, _wl(), 512,
                               LatencyTargets(tail=13e-6))
        assert rep.host_limited
        assert rep.iops_ssd_usable == pytest.approx(25e6)

    def test_gpu_device_limited_at_512(self):
        rep = analyze_platform(GPU_PLATFORM, _wl(), 512,
                               LatencyTargets(tail=13e-6))
        assert not rep.host_limited

    def test_viability_thresholds_small_on_gpu(self):
        """Paper: on GPU+GDDR+SN both T_B and T_S are < 5s."""
        rep = analyze_platform(GPU_PLATFORM, _wl(), 512,
                               LatencyTargets(tail=13e-6))
        assert rep.th.t_b < 5.0
        assert rep.th.t_s < 5.0

    def test_verdict_fields_present(self):
        rep = analyze_platform(CPU_PLATFORM, _wl(), 512)
        assert rep.verdict in {
            "viable-optimal", "viable-suboptimal", "dram-bandwidth-limited",
            "storage-limited", "jointly-insufficient", "infeasible"}
        assert rep.recommendation
        assert "tau_be" in rep.summary()

    def test_capacity_monotone_in_blocksize_economics(self):
        """Bigger blocks -> shorter tau_be -> optimal cache is a smaller
        fraction of the dataset (paper Fig. 6 discussion)."""
        frac = []
        for l in (512, 4096):
            rep = analyze_platform(CPU_PLATFORM, _wl(l), l,
                                   LatencyTargets(tail=13e-6 if l == 512
                                                  else 44e-6))
            frac.append(rep.c_dram_optimal / _wl(l).total_bytes)
        assert frac[1] <= frac[0] + 1e-9


class TestTieringPolicy:
    def test_stateless_boundaries(self):
        p = TieringPolicy(tau_hot=0.1, tau_be=5.0)
        assert p.tier_for_interval(0.01) == Tier.HBM
        assert p.tier_for_interval(1.0) == Tier.DRAM
        assert p.tier_for_interval(100.0) == Tier.FLASH

    def test_vectorized_matches_scalar(self):
        p = TieringPolicy(tau_hot=0.1, tau_be=5.0)
        ivs = [0.01, 0.5, 4.9, 5.1, 500.0]
        vec = [int(t) for t in p.tiers_for_intervals(ivs)]
        assert vec == [int(p.tier_for_interval(i)) for i in ivs]

    def test_hysteresis_blocks_thrash(self):
        p = TieringPolicy(tau_hot=0.1, tau_be=5.0, hysteresis=0.5,
                          ema_alpha=1.0)
        # interval just above tau_be but inside the band -> stays DRAM
        p.observe("k", now=0.0)
        p.observe("k", now=5.5)
        assert p.tier_of("k") == Tier.DRAM
        # far above the band -> demoted
        p.observe("k", now=5.5 + 20.0)
        assert p.tier_of("k") == Tier.FLASH

    def test_promotion_on_hot_access(self):
        p = TieringPolicy(tau_hot=0.1, tau_be=5.0, ema_alpha=1.0)
        p.observe("k", now=0.0)
        p.observe("k", now=100.0)       # cold -> FLASH eventually
        p.observe("k", now=200.0)
        assert p.tier_of("k") == Tier.FLASH
        for i in range(8):              # now very hot
            p.observe("k", now=200.0 + 0.01 * (i + 1))
        assert p.tier_of("k") in (Tier.HBM, Tier.DRAM)

    def test_from_platform_seconds_scale(self):
        p = TieringPolicy.from_platform(GPU_PLATFORM, 512,
                                        LatencyTargets(tail=13e-6))
        assert 0.5 < p.tau_be < 60.0      # the headline seconds regime
        assert p.tau_hot < p.tau_be

    def test_evict_candidates_ordering(self):
        p = TieringPolicy(tau_hot=0.1, tau_be=5.0, ema_alpha=1.0)
        for key, iv in (("a", 1.0), ("b", 3.0), ("c", 0.2)):
            p.observe(key, now=0.0)
            p.observe(key, now=iv)
        cands = p.evict_candidates(Tier.DRAM, now=10.0)
        assert cands[0] == "b"  # stalest first

    def test_evict_candidates_zero_ema_ranks_hottest(self):
        """Regression: a 0.0 EMA is *maximally hot*, not missing. The
        old `ema or now - last_seen` guard treated it as falsy and
        ranked the key by its idle gap — evicting the hottest resident
        first whenever its EMA rounded to exactly zero."""
        p = TieringPolicy(tau_hot=0.1, tau_be=5.0, ema_alpha=1.0)
        p.observe("idle", now=1.0)          # one touch: no EMA, 9s idle
        p.observe("hot", now=0.0)
        p.observe("hot", now=2.0)
        p._ema["hot"] = 0.0                 # white-box: the falsy EMA
        p.observe("warm", now=0.0)
        p.observe("warm", now=3.0)          # genuine 3.0s EMA
        cands = p.evict_candidates(Tier.DRAM, now=10.0)
        # staleness: idle=9.0 (gap), warm=3.0, hot=0.0 — the buggy
        # guard scored hot at 8.0 (gap) and evicted it before warm
        assert cands == ["idle", "warm", "hot"]
