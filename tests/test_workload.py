"""WorkloadDecl scenario compiler + per-tenant SLO economics.

Covers the declared-workload pipeline end to end:

  * spec round-trip: a `HierarchySpec` carrying a `WorkloadDecl` (all
    four arrival kinds, session presets, per-tenant SLOs) survives
    to_json -> from_json byte-exactly;
  * purity (property test): every compiled product — jobs, trace,
    id_steps — is a pure function of (spec JSON, seed): byte-identical
    across compile -> to_json -> from_json -> compile;
  * per-tenant economics: `tenant_taus` monotone in `alpha_stall`,
    the compiled gate carries per-tenant tau_be overrides and declared
    priors under `isolation="per-tenant"` and none under `"shared"`;
  * the tenant classifier recovers the tenant from both key shapes;
  * scheduler integration: declared multi-tenant jobs keep the
    continuous-vs-lockstep token equivalence and produce per-tenant
    report rows (p99 per-token stall, event counters);
  * the isolation headline (`repro.serving.tenants`): with per-tenant
    gating the scan-flood adversary cannot push the premium tenant's
    p99 per-token stall past its declared budget; the same pack under a
    single shared gate violates it; without the adversary the shared
    gate meets it (causality).
"""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.platform.spec import (ArrivalDecl, HierarchySpec,
                                 SessionShapeDecl, SloDecl, TenantDecl,
                                 WorkloadDecl)
from repro.platform.workload import compile_workload, tenant_classifier


def _pack_decl(seed=0, isolation="per-tenant"):
    return WorkloadDecl(
        tenants=(
            TenantDecl(name="premium", n_sessions=3,
                       session=SessionShapeDecl.chat(),
                       arrival=ArrivalDecl(kind="flash_crowd",
                                           peak_step=6, burst_len=4),
                       slo=SloDecl(deadline_steps=4,
                                   p99_stall_budget=2e-6,
                                   alpha_stall=4.0)),
            TenantDecl(name="rag", n_sessions=2,
                       session=SessionShapeDecl.rag(),
                       arrival=ArrivalDecl(kind="diurnal", period=48)),
            TenantDecl(name="scan", n_sessions=4,
                       session=SessionShapeDecl.scan(),
                       arrival=ArrivalDecl(kind="scan_flood", period=24,
                                           burst_len=4,
                                           background_per_step=6)),
        ),
        horizon_steps=64, seed=seed, isolation=isolation)


# ---------------------------------------------------------------------------
# declaration + JSON round-trip
# ---------------------------------------------------------------------------

def test_workload_spec_round_trips_byte_exactly():
    spec = HierarchySpec(workload=_pack_decl())
    blob = spec.to_json()
    back = HierarchySpec.from_json(blob)
    assert back == spec
    assert back.to_json() == blob          # byte-stable for CI pinning


def test_workload_validation_errors_are_actionable():
    dup = WorkloadDecl(tenants=(TenantDecl(name="a"),
                                TenantDecl(name="a")))
    with pytest.raises(ValueError, match="unique"):
        dup.validate()
    with pytest.raises(ValueError, match="without '/'"):
        WorkloadDecl(tenants=(TenantDecl(name="a/b"),)).validate()
    with pytest.raises(ValueError, match="isolation"):
        WorkloadDecl(tenants=(TenantDecl(name="a"),),
                     isolation="siloed").validate()
    with pytest.raises(ValueError, match="at least one tenant"):
        WorkloadDecl().validate()
    with pytest.raises(ValueError, match="arrival kind"):
        ArrivalDecl(kind="poisson").validate("t.arrival")
    with pytest.raises(ValueError, match="p99_stall_budget"):
        SloDecl(p99_stall_budget=0.0).validate("t.slo")


def test_arrival_intensity_shapes():
    n = 48
    flat = ArrivalDecl(kind="stationary").intensity(n)
    assert flat.shape == (n,) and np.all(flat == 1.0)
    flood = ArrivalDecl(kind="scan_flood", period=16, burst_len=4,
                        baseline=0.1).intensity(n)
    assert np.all(flood[(np.arange(n) % 16) < 4] == 1.0)
    assert np.all(flood[(np.arange(n) % 16) >= 4] == 0.1)
    day = ArrivalDecl(kind="diurnal", period=n, baseline=0.2).intensity(n)
    assert day.min() >= 0.2 - 1e-12 and day.max() <= 1.0 + 1e-12
    crowd = ArrivalDecl(kind="flash_crowd", peak_step=10, burst_len=4,
                        baseline=0.05).intensity(n)
    assert crowd[10] == 1.0 and crowd[30] == 0.05


# ---------------------------------------------------------------------------
# compiled products: shape + purity
# ---------------------------------------------------------------------------

def _job_fingerprint(jobs):
    return [(j.sid, j.tenant, j.prompt.tobytes(),
             tuple((t.due_step, t.max_new, t.deadline_steps)
                   for t in j.turns)) for j in jobs]


def test_compiled_jobs_are_tenant_tagged_and_ordered():
    cw = compile_workload(_pack_decl())
    jobs = cw.jobs(vocab=64)
    assert len(jobs) == 3 + 2 + 4
    for j in jobs:
        tenant, idx = j.sid.split("/")
        assert j.tenant == tenant and len(idx) == 3
        dues = [t.due_step for t in j.turns]
        assert dues == sorted(dues) and len(set(dues)) == len(dues)
    prem = [j for j in jobs if j.tenant == "premium"]
    assert all(t.deadline_steps == 4 for j in prem for t in j.turns)


def test_trace_and_id_steps_agree_on_access_counts():
    cw = compile_workload(_pack_decl())
    trace = cw.trace()
    steps, n_session_ids, n_ids = cw.id_steps()
    assert n_session_ids == 9
    assert len(steps) == len(trace.steps) == 64
    for ts, ids in zip(trace.steps, steps):
        assert len(ts) == ids.size
    flat = np.concatenate([s for s in steps if s.size])
    assert flat.min() >= 0 and flat.max() < n_ids
    # every tenant key in the trace carries its tenant as the class head
    names = {t.name for t in cw.decl.tenants}
    assert {k[0] for s in trace.steps for k in s} <= names


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=1 << 16),
       st.sampled_from(ArrivalDecl.KINDS),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=8))
def test_compile_is_pure_in_spec_json_and_seed(seed, kind, n_sessions,
                                               n_turns, background):
    """compile -> to_json -> from_json -> compile is byte-identical for
    jobs, traces and id_steps — the determinism contract CI's
    double-run diff rests on."""
    decl = WorkloadDecl(
        tenants=(TenantDecl(
            name="t0", n_sessions=n_sessions,
            session=SessionShapeDecl(n_turns=n_turns, gap_steps=3,
                                     gap_jitter=0.4),
            arrival=ArrivalDecl(kind=kind,
                                background_per_step=background,
                                background_pool=32)),),
        horizon_steps=40, seed=seed)
    spec = HierarchySpec(workload=decl)
    spec2 = HierarchySpec.from_json(spec.to_json())
    a, b = compile_workload(decl), compile_workload(spec2.workload)
    assert _job_fingerprint(a.jobs()) == _job_fingerprint(b.jobs())
    assert a.trace().steps == b.trace().steps
    sa, na, ia = a.id_steps()
    sb, nb, ib = b.id_steps()
    assert na == nb and ia == ib
    assert all(np.array_equal(x, y) for x, y in zip(sa, sb))


def test_different_seeds_draw_different_schedules():
    a = compile_workload(_pack_decl(seed=0))
    b = compile_workload(_pack_decl(seed=1))
    assert _job_fingerprint(a.jobs()) != _job_fingerprint(b.jobs())


# ---------------------------------------------------------------------------
# per-tenant economics
# ---------------------------------------------------------------------------

def test_tenant_taus_monotone_in_alpha_stall():
    from repro.core.economics import GPU_GDDR
    from repro.core.ssd_model import NAND_TYPES, storage_next_ssd
    ssd = storage_next_ssd(NAND_TYPES["slc"])
    taus = {}
    for alpha in (0.0, 1.0, 4.0, 16.0):
        decl = WorkloadDecl(tenants=(TenantDecl(
            name="t", slo=SloDecl(alpha_stall=alpha)),))
        taus[alpha] = compile_workload(decl).tenant_taus(
            GPU_GDDR, ssd, 32768, fetch_seconds=1e-4)["t"]
    assert taus[0.0] < taus[1.0] < taus[4.0] < taus[16.0]
    # no stall pricing -> the plain Eq. 1 threshold, alpha irrelevant
    decl = WorkloadDecl(tenants=(TenantDecl(
        name="t", slo=SloDecl(alpha_stall=16.0)),))
    flat = compile_workload(decl).tenant_taus(GPU_GDDR, ssd, 32768,
                                              fetch_seconds=0.0)["t"]
    assert flat == pytest.approx(taus[0.0])


def test_tenant_classifier_recovers_both_key_shapes():
    classify = tenant_classifier(["premium", "scan"])
    assert classify(("kv", "premium/003")) == "premium"
    assert classify(("scan", 17)) == "scan"
    assert classify(("kv", "unknown/001")) == "kv"     # fallback
    assert classify(("kv", "r1")) == "kv"
    assert classify((0, 3)) == "expert"
    assert classify("loose") == "obj"


def test_compile_wires_per_tenant_gate_and_priors():
    from repro.platform import Platform
    from repro.serving.tenants import tenant_pack
    spec = tenant_pack()
    plat = Platform.compile(spec)
    gate = plat.policy(0)
    names = {t.name for t in spec.workload.tenants}
    assert set(gate.class_tau_be) == names
    # premium's alpha_stall widens its own threshold only
    assert gate.class_tau_be["premium"] > gate.class_tau_be["scan"]
    assert gate.tau_for(("kv", "premium/000")) \
        == gate.class_tau_be["premium"]
    assert gate.tau_for(("kv", "nobody/000")) == gate.tau_be
    # declared think gaps seed per-tenant priors (gap_steps * step_time)
    st_ = spec.resolved_step_time()
    for t in spec.workload.tenants:
        q = plat.tracker.class_quantile(t.name, 0.5)
        assert q == pytest.approx(t.session.gap_steps * st_, rel=0.3)
    # the shared control arm: one threshold, no per-tenant overrides
    shared = dataclasses.replace(
        spec, workload=dataclasses.replace(spec.workload,
                                           isolation="shared"))
    gate2 = Platform.compile(shared).policy(0)
    assert gate2.class_tau_be is None
    assert gate2.tau_for(("kv", "premium/000")) == gate2.tau_be


def test_platform_jobs_requires_declared_workload():
    from repro.platform import Platform
    plat = Platform.compile(HierarchySpec())
    with pytest.raises(ValueError, match="no workload"):
        plat.jobs()
    plat2 = Platform.compile(HierarchySpec(workload=_pack_decl()))
    assert plat2.workload() is plat2.workload()        # cached
    assert len(plat2.jobs()) == 9


# ---------------------------------------------------------------------------
# scheduler integration (decode; module-scoped model fixture)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rules, params


def _engine(cfg, params, rules):
    from repro.core.policy import TieringPolicy
    from repro.runtime.clock import VirtualClock
    from repro.runtime.tiers import TieredStore
    from repro.serving import DecodeEngine
    store = TieredStore(
        TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0),
        clock=VirtualClock())
    return DecodeEngine(cfg, params, rules, max_slots=3, max_len=64,
                        store=store, step_time=2e-3)


def test_declared_multi_tenant_jobs_token_equivalence(setup):
    """The declared generator slots into the continuous-vs-lockstep
    race: byte-identical tokens and per-tenant report rows."""
    from repro.serving import compare_scheduling, jobs_from_trace
    cfg, rules, params = setup
    cell = compare_scheduling(
        lambda: _engine(cfg, params, rules),
        lambda: jobs_from_trace("multi_tenant", n_jobs=5, n_turns=2,
                                tokens_per_turn=4, vocab=cfg.vocab,
                                horizon=48, seed=3),
        pause_idle_steps=4)
    assert cell["tokens_identical"], cell["token_mismatches"]
    tenants = cell["continuous"].get("tenants", {})
    assert set(tenants) == {"tenant_a", "tenant_b"}
    for name, d in tenants.items():
        assert d["sessions"] >= 1 and d["tokens"] > 0
        assert d["p99_per_token_stall"] >= 0.0
        for field in ("admissions", "resumes", "unparks", "parks",
                      "pauses", "deadline_misses", "per_token_stall"):
            assert field in d
    assert (tenants["tenant_a"]["tokens"] + tenants["tenant_b"]["tokens"]
            == cell["continuous"]["tokens"])


def test_paused_kv_blob_matches_declared_block_size(setup):
    """The tenant pack prices DRAM in KV-blob units; pin the blob size
    the engine actually produces so capacity arithmetic cannot drift
    silently."""
    import jax
    from repro.serving.engine import Request
    from repro.serving.tenants import KV_BLOB_BYTES
    cfg, rules, params = setup
    eng = _engine(cfg, params, rules)
    eng.admit(Request(rid="probe", prompt=np.arange(1, 6, dtype=np.int32),
                      max_new=8))
    eng.step()
    eng.pause("probe")
    blob = eng.store.get(("kv", "probe"))
    nbytes = sum(np.asarray(x).nbytes
                 for x in jax.tree_util.tree_leaves(blob))
    assert nbytes == KV_BLOB_BYTES


def test_isolation_headline_holds(setup):
    """The PR's acceptance bar: per-tenant gating keeps premium's p99
    per-token stall inside its declared budget under the scan flood;
    one shared gate on the identical pack violates it; removing the
    adversary clears the shared gate too (the flood is causal)."""
    from repro.serving.tenants import run_tenant_bench
    report = run_tenant_bench()
    v = report["verdicts"]["premium"]
    assert v["gated_meets_budget"], v
    assert v["shared_violates"], v
    assert v["adversary_causal"], v
    assert report["isolation_effective"]
    # the mechanism, not just the outcome: the gated arm prices the
    # flood out of DRAM (scan tau stays at the fleet baseline, premium's
    # widens), and the shared arm admits it
    assert report["gated"]["tau_be"]["premium"] \
        > report["gated"]["tau_be"]["scan"]
    assert report["shared"]["tau_be"]["premium"] \
        == report["shared"]["tau_be"]["scan"]
    # Eq. 1 stall-ledger conservation on every arm: the ledger total
    # equals kv stall + slot-idle rent to 1e-9 relative, and the slice
    # attributed to named tenants never exceeds the non-idle total
    from repro.serving.tenants import STEP_TIME
    for arm in ("gated", "shared", "no_adversary"):
        m = report[arm]["report"]
        led = m["stall_ledger"]
        rhs = m["kv_stall"] + STEP_TIME * m["slot_idle_steps"]
        assert abs(led["total"] - rhs) <= 1e-9 * max(rhs, 1e-30), arm
        tenant_slice = sum(c["ledger_stall"]
                           for c in m["tenants"].values())
        assert tenant_slice <= led["total"] - led["scheduler_idle"] \
            + 1e-12, arm
    # the shared arm's premium violation is visible as budget burn > 1
    # in the same currency the verdicts use
    assert "budget_burn" in report["shared"]["report"]["tenants"]["premium"]
    # JSON-stable: the report round-trips through json bytes unchanged
    blob = json.dumps(report, sort_keys=True)
    assert json.loads(blob) == json.loads(
        json.dumps(json.loads(blob), sort_keys=True))
