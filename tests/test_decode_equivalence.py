"""Serving-path correctness: prefill + token-by-token decode must produce
the same logits as the parallel (train-mode) forward pass, for every
mixer family (attention / GQA / MQA / cross-attn / mamba2 / mLSTM / sLSTM).

This exercises every cache mechanism: KV write/read, select-based decode
updates, conv states, SSD recurrent states, and the zamba shared-attention
cache."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.parallel.sharding import single_device_rules

ARCHS = ["deepseek-7b", "gemma-2b", "qwen3-moe-235b-a22b", "zamba2-7b",
         "xlstm-350m", "whisper-medium", "qwen2-vl-2b"]


@pytest.fixture(scope="module")
def rules():
    return single_device_rules()


def _no_drop(cfg):
    """Raise MoE capacity so no token is ever dropped: the capacity is a
    function of the *call's* token count, so prefill(S0) and forward(S)
    drop different tokens at finite capacity — by design (GShard)."""
    import dataclasses
    from repro.models.config import MoeSpec

    def fix(layer):
        return tuple(dataclasses.replace(s, capacity_factor=64.0)
                     if isinstance(s, MoeSpec) else s for s in layer)

    return dataclasses.replace(
        cfg, pattern=tuple(fix(l) for l in cfg.pattern),
        tail=tuple(fix(l) for l in cfg.tail))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rules):
    cfg = _no_drop(get_config(arch, reduced=True))
    B, S = 2, 12
    key = jax.random.PRNGKey(0)
    params, _ = M.init_params(key, cfg)
    # f32 compute for a tight comparison
    dt = jnp.float32

    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.d_model),
            dt) * 0.1
    if cfg.modality == "vlm":
        # keep it text-only for equivalence (vision path tested in smoke)
        pass

    logits_par, _ = M.forward(params, cfg, rules, batch, compute_dtype=dt,
                              remat=False)

    # prefill on the first S0 tokens, then decode the rest one by one
    S0 = 5
    cache = M.init_cache(cfg, B, S, dtype=dt)
    cache, logits_pre = M.prefill(
        params, cfg, rules, dict(batch, tokens=tokens[:, :S0]), cache,
        compute_dtype=dt)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_par[:, S0 - 1]),
        rtol=2e-4, atol=2e-4)

    for t in range(S0, S):
        cache, logits_dec = M.decode_step(
            params, cfg, rules, tokens[:, t:t + 1], cache,
            jnp.asarray(t, jnp.int32), compute_dtype=dt)
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_par[:, t]),
            rtol=5e-4, atol=5e-4,
            err_msg=f"{arch}: decode step {t} diverged")
