"""Platform-API tests: spec validation + JSON round-trip, compile
parity with the keyword dialect, the capacity-weighted ring's fairness
and stall win, uniform handles, the closed provisioning loop's
acceptance criterion, the legacy constructor shims, the splice-jit
cache, and the roofline step-time hook."""
import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.core.policy import Tier, TieringPolicy
from repro.platform import (AutoscaleDecl, HierarchySpec, HostDecl,
                            NetDecl, Platform, PolicyDecl, TierDecl,
                            TopologyDecl, measured_step_time,
                            run_autoscale_bench)
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import ShardedTieredStore
from repro.serving.bench import multi_host_session_bench


def _pinned(_h=0):
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


# ---------------------------------------------------------------------------
# spec validation: invalid specs raise with actionable messages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec, fragment", [
    (HierarchySpec(hosts=()), "at least one host"),
    (HierarchySpec(hosts=(HostDecl(
        tiers={"dram": TierDecl(0.0, 45e9, 5e-7)}),)),
     "capacity_bytes must be > 0"),
    (HierarchySpec(hosts=(HostDecl(
        tiers={"l2": TierDecl(1e9, 1e9, 1e-7)}),)), "unknown tier"),
    (HierarchySpec(policy=PolicyDecl(kind="lru")), "unknown policy kind"),
    (HierarchySpec(policy=PolicyDecl(kind="static")),
     "needs explicit tau_hot"),
    (HierarchySpec(policy=PolicyDecl(host_profile="tpu")),
     "unknown host_profile"),
    (HierarchySpec(hosts=(HostDecl(count=3),), weights=(1.0, 2.0)),
     "2 ring weights for 3 hosts"),
    (HierarchySpec(weights=(-1.0,)), "weights must be positive"),
    (HierarchySpec(weighting="dram"), "unknown weighting"),
    (HierarchySpec(clock="sundial"), "unknown clock source"),
    (HierarchySpec(step_time="profiled"), "seconds or 'measured'"),
    (HierarchySpec(class_priors={"kv": -1.0}), "positive seconds"),
    (HierarchySpec(replicas=0), "must be >= 1"),
    (HierarchySpec(write_shield_depth=0), "shield forever"),
    (HierarchySpec(rebalance_rate=-5.0), "positive bytes/s"),
    (HierarchySpec(autoscale=AutoscaleDecl(min_hosts=4, max_hosts=2)),
     "max_hosts=2 < min_hosts=4"),
    (HierarchySpec(autoscale=AutoscaleDecl(template=3)), "out of range"),
])
def test_invalid_specs_raise_actionable(spec, fragment):
    with pytest.raises(ValueError, match=fragment):
        spec.validate()


def test_factory_policy_compiles_but_does_not_serialize():
    spec = HierarchySpec(hosts=(HostDecl(count=2),), policy=_pinned)
    platform = Platform.compile(spec)
    assert platform.n_hosts == 2
    assert platform.policy(0).tau_be == 1e-9
    with pytest.raises(ValueError, match="cannot be serialized"):
        spec.to_json()
    with pytest.raises(ValueError, match="no advisor"):
        platform.advise()


# ---------------------------------------------------------------------------
# JSON round-trip: equality and identical compiled behavior
# ---------------------------------------------------------------------------

def _rich_spec():
    return HierarchySpec(
        hosts=(HostDecl(tiers={"dram": TierDecl(256e9, 45e9, 5e-7)}),
               HostDecl(count=3)),
        policy=PolicyDecl.economic(l_blk=64 << 10, alpha_stall=2.0),
        topology=TopologyDecl(hosts_per_rack=2),
        net=NetDecl(rtt=30e-6),
        class_priors={"kv": 2.0, "expert": 0.5},
        replicas=2, vnodes=96, write_shield_depth=3,
        rebalance_rate=2e9, step_time=1e-3,
        autoscale=AutoscaleDecl(max_hosts=6, active_window=4.0))


def test_spec_json_round_trip_equal():
    spec = _rich_spec()
    again = HierarchySpec.from_json(spec.to_json())
    assert again == spec
    assert again.to_json() == spec.to_json()        # byte-stable


def test_from_json_rejects_garbage():
    with pytest.raises(ValueError, match="not valid JSON"):
        HierarchySpec.from_json("{nope")
    with pytest.raises(ValueError, match="unknown fields"):
        HierarchySpec.from_json(json.dumps({"n_hosts": 4}))
    bad = json.loads(HierarchySpec().to_json())
    bad["version"] = 99
    with pytest.raises(ValueError, match="version 99"):
        HierarchySpec.from_json(json.dumps(bad))


def test_round_tripped_spec_compiles_to_identical_smoke_bench():
    spec = HierarchySpec(hosts=(HostDecl(count=4),),
                         policy=PolicyDecl.pinned_flash())
    kw = dict(n_sessions=6, rounds=1, kv_bytes=1 << 18, decode_steps=4,
              step_time=1e-3, lead=2, seed=0)
    a = multi_host_session_bench("async", spec=spec, **kw)
    b = multi_host_session_bench(
        "async", spec=HierarchySpec.from_json(spec.to_json()), **kw)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


# ---------------------------------------------------------------------------
# compile parity: the declarative path reproduces the keyword dialect
# ---------------------------------------------------------------------------

def test_homogeneous_spec_matches_classic_bench_byte_identical():
    kw = dict(n_sessions=8, rounds=2, kv_bytes=1 << 19, decode_steps=8,
              step_time=2e-3, lead=6, skew=1.2, seed=0)
    classic = multi_host_session_bench("async", n_hosts=4, **kw)
    spec = HierarchySpec(hosts=(HostDecl(count=4),),
                         policy=PolicyDecl.pinned_flash())
    declared = multi_host_session_bench("async", spec=spec, **kw)
    assert json.dumps(classic, sort_keys=True) == \
        json.dumps(declared, sort_keys=True)


def test_heterogeneous_spec_equal_weights_matches_homogeneous():
    """The acceptance shape: a heterogeneous 4-host spec (one host with
    2x DRAM) run with uniform ring weights reproduces the homogeneous
    keyword-dialect smoke record byte-for-byte — capacity skew only
    changes behavior through the weighting, never through the pinned
    flash restore path."""
    kw = dict(n_sessions=8, rounds=2, kv_bytes=1 << 19, decode_steps=8,
              step_time=2e-3, lead=6, skew=0.0, seed=0)
    classic = multi_host_session_bench("async", n_hosts=4, **kw)
    het = HierarchySpec(
        hosts=(HostDecl(tiers={"dram": TierDecl(256e9, 45e9, 5e-7)}),
               HostDecl(count=3)),
        policy=PolicyDecl.pinned_flash(), weighting="uniform")
    declared = multi_host_session_bench("async", spec=het, **kw)
    assert json.dumps(classic, sort_keys=True) == \
        json.dumps(declared, sort_keys=True)


def test_spec_conflicting_kwargs_rejected():
    spec = HierarchySpec(hosts=(HostDecl(count=2),),
                         policy=PolicyDecl.pinned_flash())
    with pytest.raises(ValueError, match="rebalance_rate"):
        multi_host_session_bench("async", spec=spec, rebalance_rate=1e9,
                                 n_sessions=2, rounds=1)


def test_equal_weights_reproduce_unweighted_ring():
    classic = ShardedTieredStore(4, policy_factory=_pinned,
                                 clock=VirtualClock())
    p = Platform.compile(HierarchySpec(hosts=(HostDecl(count=4),),
                                       policy=PolicyDecl.pinned_flash()))
    assert p.fabric._ring_points == classic._ring_points
    assert p.fabric._ring_hosts == classic._ring_hosts


# ---------------------------------------------------------------------------
# heterogeneous hosts: weighted-ring fairness + the stall win
# ---------------------------------------------------------------------------

def test_weighted_ring_fairness_two_to_one():
    """2:1 capacity weights -> key ownership within 5% of 2:1 on 1000
    keys (guards the weighted-ring hash mixing)."""
    spec = HierarchySpec(
        hosts=(HostDecl(tiers={"dram": TierDecl(256e9, 45e9, 5e-7)}),
               HostDecl(tiers={"dram": TierDecl(128e9, 45e9, 5e-7)})),
        policy=PolicyDecl.pinned_flash(), vnodes=128)
    assert spec.resolved_weights() == [2.0, 1.0]
    fabric = Platform.compile(spec).fabric
    counts = {0: 0, 1: 0}
    for i in range(1000):
        counts[fabric.owner(("kv", f"s{i}"))] += 1
    ratio = counts[0] / counts[1]
    assert 2.0 * 0.95 <= ratio <= 2.0 * 1.05, counts


def _het_spec(weighting):
    small = 3 * (1 << 19)           # three sessions' worth of DRAM
    return HierarchySpec(
        hosts=(HostDecl(tiers={"dram": TierDecl(2 * small, 45e9, 5e-7)}),
               HostDecl(tiers={"dram": TierDecl(small, 45e9, 5e-7)},
                        count=3)),
        policy=PolicyDecl.pinned_dram(), weighting=weighting, vnodes=128)


def test_capacity_weighting_beats_uniform_on_skewed_dram():
    """One host with 2x DRAM: the capacity-weighted ring keeps the
    DRAM-resident working set placed, the uniform ring overflows the
    small hosts onto flash — measurably more restore stall."""
    kw = dict(kv_tier=Tier.DRAM, n_sessions=14, rounds=3,
              kv_bytes=1 << 19, decode_steps=8, step_time=2e-3, lead=6,
              seed=0)
    weighted = multi_host_session_bench(
        "sync", spec=_het_spec("capacity"), **kw)
    uniform = multi_host_session_bench(
        "sync", spec=_het_spec("uniform"), **kw)
    assert weighted["per_token_stall"] < uniform["per_token_stall"]


# ---------------------------------------------------------------------------
# uniform handles
# ---------------------------------------------------------------------------

def test_kv_session_handle_idiom():
    spec = HierarchySpec(hosts=(HostDecl(count=2),),
                         policy=PolicyDecl.pinned_flash(), replicas=2)
    p = Platform.compile(spec)
    sess = p.kv_session("u1")
    blob = np.arange(1 << 14, dtype=np.float32)
    wh = sess.save(blob, tier=Tier.FLASH)
    assert wh.done() and wh.result() is None        # writes never block
    p.drain()
    assert sess.tier() == Tier.FLASH
    h1 = sess.prefetch()
    assert sess.prefetch() is h1                    # idempotent in flight
    assert not h1.done()
    p.fabric.hosts[sess.preferred_host()].runtime.advance(1.0)
    assert h1.done()
    np.testing.assert_array_equal(h1.result(), blob)
    assert h1.result() is h1.result()               # cached after wait
    assert sess.prefetch() is not h1                # consumed -> fresh
    assert sess.lead_steps(1e-3) >= 1
    # replica-aware routing rebinds to a holder host
    assert sess.route().host in p.fabric.holders(sess.key)
    np.testing.assert_array_equal(sess.resume(), blob)


def test_wall_clock_compile_and_passthroughs():
    from repro.runtime.clock import WallClock
    spec = HierarchySpec(hosts=(HostDecl(),), clock="wall",
                         policy=PolicyDecl.pinned_flash())
    p = Platform.compile(spec)
    assert isinstance(p.clock, WallClock)
    sess = p.kv_session("w")
    sess.save(np.zeros(64, np.float32), tier=Tier.FLASH)
    p.drain()
    p.reset_stats()
    assert p.summary()["hosts"] == 1.0
    assert "host 0:" in p.report()


def test_platform_expert_store_and_engine_are_warning_free():
    spec = HierarchySpec(hosts=(HostDecl(count=2),),
                         policy=PolicyDecl.pinned_flash())
    p = Platform.compile(spec)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        es = p.expert_store(n_layers=1, n_experts=4, host=1, replicas=2)
    assert es.host == 1                 # host identity from the view
    assert es.store.replicas == 2
    es.store.put((0, 0), np.zeros(256, np.float32), tier=Tier.FLASH)
    p.drain()
    assert len(p.fabric.holders((0, 0))) == 2


# ---------------------------------------------------------------------------
# closed provisioning loop: the acceptance criterion
# ---------------------------------------------------------------------------

def test_autoscale_diurnal_closed_loop_acceptance():
    """On the diurnal trace the loop adds a host during the peak,
    removes it off-peak, ends within one host of the advisor's final
    recommendation, at <= the static fleet's modeled $/token."""
    r = run_autoscale_bench(n_steps=240)
    a = r["autoscaled"]
    actions = [d["action"] for d in a["decisions"]]
    assert "add" in actions, a["decisions"]
    assert "remove" in actions, a["decisions"]
    add_step = next(d["step"] for d in a["decisions"]
                    if d["action"] == "add")
    remove_step = next(d["step"] for d in a["decisions"]
                       if d["action"] == "remove")
    # the peak is the diurnal overlap (middle third); off-peak follows
    assert 240 / 3 <= add_step < remove_step
    assert a["hosts_peak"] > a["hosts_start"]
    assert a["hosts_final"] < a["hosts_peak"]
    assert r["final_within_one_of_advice"]
    assert r["autoscale_wins"], (a["cost_per_token"],
                                 r["static"]["cost_per_token"])


def test_autoscale_bench_deterministic_in_process():
    kw = dict(n_steps=60, every=10)
    a = run_autoscale_bench(**kw)
    b = run_autoscale_bench(**kw)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_autoscaler_never_underprovisions_heterogeneous_fleet():
    """The advisor's host count is denominated in template-host DRAM;
    on a mixed fleet, count-matching by retiring small hosts would
    strand the hot set below its byte target. The loop must hold
    instead (and still retire when capacity allows)."""
    from types import SimpleNamespace
    blk = 1 << 20
    spec = HierarchySpec(
        hosts=(HostDecl(tiers={"dram": TierDecl(20 * blk, 45e9, 5e-7)}),
               HostDecl(tiers={"dram": TierDecl(5 * blk, 45e9, 5e-7)},
                        count=3)),
        policy=PolicyDecl.economic(l_blk=blk),
        autoscale=AutoscaleDecl(min_hosts=1, max_hosts=8,
                                cooldown_steps=0, template=0))
    p = Platform.compile(spec)          # 35 blocks of fleet DRAM

    def stub_advise(target_blocks, horizon=None):
        return SimpleNamespace(recommended_hosts=2,
                               recommended_dram_bytes=target_blocks * blk,
                               limit="none", bandwidth_limited=False)

    # target 33.3 blocks: dropping any 5-block host under-provisions
    p.advise = lambda horizon=None: stub_advise(33.3)
    d = p.autoscale(0)
    assert d.action == "hold" and p.n_hosts == 4
    # target 20 blocks: the newest small host can safely retire
    p.advise = lambda horizon=None: stub_advise(20.0)
    d = p.autoscale(1)
    assert d.action == "remove" and p.n_hosts == 3


def test_autoscaler_respects_cooldown_and_bounds():
    spec = dataclasses.replace(
        HierarchySpec(hosts=(HostDecl(count=2),),
                      policy=PolicyDecl.economic(l_blk=1 << 16)),
        autoscale=AutoscaleDecl(min_hosts=2, max_hosts=2,
                                cooldown_steps=5))
    p = Platform.compile(spec)
    # empty fleet: advisor recommends 1 but min_hosts clamps to 2
    d = p.autoscale(0)
    assert d.action == "hold" and p.n_hosts == 2
    assert d.recommended == 2


def test_autoscaler_acts_on_bandwidth_limited_verdicts():
    """Regression: the loop only compared DRAM capacity to the hot-set
    byte target, so a `dram-bandwidth`/`ssd-bandwidth` verdict (T_B/T_S
    binding — Eq. 2/3) was ignored: no scale-up when more bytes on the
    same hosts can't help, and worse, retirement of the very spindles
    absorbing the demand."""
    from types import SimpleNamespace
    blk = 1 << 20
    spec = HierarchySpec(
        hosts=(HostDecl(tiers={"dram": TierDecl(20 * blk, 45e9, 5e-7)},
                        count=2),),
        policy=PolicyDecl.economic(l_blk=blk),
        autoscale=AutoscaleDecl(min_hosts=1, max_hosts=3,
                                cooldown_steps=0))
    p = Platform.compile(spec)

    def advice(target_blocks, limit):
        return SimpleNamespace(
            recommended_hosts=2,
            recommended_dram_bytes=target_blocks * blk,
            limit=limit, t_b=0.5, t_s=1.5,
            bandwidth_limited=limit in ("dram-bandwidth",
                                        "ssd-bandwidth"))

    # capacity covers the hot set (10 < 40 blocks) but the DRAM wire is
    # the binding constraint: add a host to spread the demand
    p.advise = lambda horizon=None: advice(10.0, "dram-bandwidth")
    d = p.autoscale(0)
    assert d.action == "add" and p.n_hosts == 3
    assert "dram-bandwidth-limited" in d.reason and "T_B" in d.reason

    # still limited at max_hosts: hold — and the reason says why; the
    # remove branch must NOT fire despite 30 blocks of headroom
    d = p.autoscale(1)
    assert d.action == "hold" and p.n_hosts == 3
    assert "max_hosts" in d.reason

    p.advise = lambda horizon=None: advice(10.0, "ssd-bandwidth")
    d = p.autoscale(2)
    assert d.action == "hold" and p.n_hosts == 3

    # the same headroom with the verdict cleared retires the host
    p.advise = lambda horizon=None: advice(10.0, "none")
    d = p.autoscale(3)
    assert d.action == "remove" and p.n_hosts == 2


# ---------------------------------------------------------------------------
# advisor staleness window (what makes scale-down possible)
# ---------------------------------------------------------------------------

def test_advisor_active_window_releases_stale_pool():
    from repro.autopilot.advisor import ProvisionAdvisor
    from repro.core.economics import GPU_GDDR
    from repro.core.ssd_model import storage_next_ssd
    from repro.runtime.tiers import TieredStore
    from repro.autopilot.gate import EconomicGate

    clock = VirtualClock()
    gate = EconomicGate(tau_hot=1e-3, tau_be=10.0)
    store = TieredStore(gate, clock=clock)
    blob = np.zeros(1 << 14, np.float32)
    for i in range(8):
        store.put(("kv", f"a{i}"), blob)
    for _ in range(4):                      # demonstrate ~1s reuse
        clock.advance(1.0)
        for i in range(8):
            store.get(("kv", f"a{i}"))
    clock.advance(50.0)                     # pool A goes idle
    for i in range(8):                      # pool B takes over
        store.put(("kv", f"b{i}"), blob)
    for _ in range(4):
        clock.advance(1.0)
        for i in range(8):
            store.get(("kv", f"b{i}"))
    kw = dict(l_blk=float(blob.nbytes))
    plain = ProvisionAdvisor(GPU_GDDR, storage_next_ssd(),
                             **kw).advise(gate.tracker, store=store)
    windowed = ProvisionAdvisor(GPU_GDDR, storage_next_ssd(),
                                active_window=10.0,
                                **kw).advise(gate.tracker, store=store)
    # the stale pool stops counting toward the hot set
    assert windowed.hot_bytes < plain.hot_bytes
    assert windowed.hot_bytes <= 8 * blob.nbytes + 1


# ---------------------------------------------------------------------------
# legacy constructor shims: deprecated but functional (the only test
# allowed to trigger DeprecationWarning — see the CI deprecation gate)
# ---------------------------------------------------------------------------

def test_legacy_fabric_dialects_warn_but_work():
    from repro.tiering.expert_store import ExpertStore
    fab = ShardedTieredStore(2, policy_factory=_pinned,
                             clock=VirtualClock())
    with pytest.warns(DeprecationWarning, match="ExpertStore"):
        es = ExpertStore(n_layers=1, n_experts=2, policy=_pinned(),
                         fabric=fab, host=1, replicas=2)
    assert es.host == 1
    es.store.put((0, 0), np.zeros(64, np.float32), tier=Tier.FLASH)
    fab.drain()
    assert len(fab.holders((0, 0))) == 2


def test_legacy_engine_dialect_warns(setup_engine):
    from repro.serving.engine import DecodeEngine
    cfg, rules, params = setup_engine
    fab = ShardedTieredStore(2, policy_factory=_pinned,
                             clock=VirtualClock())
    with pytest.warns(DeprecationWarning, match="DecodeEngine"):
        eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                           fabric=fab, host=1)
    assert eng.host == 1
    assert eng.store.fabric is fab


# ---------------------------------------------------------------------------
# splice-jit cache: pow2 prompt buckets + traced-slot splices
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup_engine():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rules, params


def test_prompt_bucketing_one_compile_per_bucket(setup_engine):
    from repro.serving.engine import DecodeEngine, Request
    cfg, rules, params = setup_engine
    eng = DecodeEngine(cfg, params, rules, max_slots=4, max_len=64)
    assert eng._bucket_prompts
    rng = np.random.default_rng(0)
    for i, n in enumerate((5, 7, 8)):       # one pow2 bucket: 8
        eng.admit(Request(rid=f"r{i}",
                          prompt=rng.integers(1, cfg.vocab, n).astype(
                              np.int32)))
    assert eng.jit_stats["prefill_traces"] == 1
    eng.admit(Request(rid="r9", prompt=rng.integers(
        1, cfg.vocab, 9).astype(np.int32)))  # next bucket: 16
    assert eng.jit_stats["prefill_traces"] == 2


def test_bucketed_admit_matches_exact_generation(setup_engine):
    """Pad-to-bucket prefill must not change greedy generation: causal
    masking keeps real positions pad-independent and decode masks
    beyond the fill index."""
    from repro.serving.engine import DecodeEngine, Request
    cfg, rules, params = setup_engine
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab, 6).astype(np.int32)

    bucketed = DecodeEngine(cfg, params, rules, max_slots=1, max_len=64)
    exact = DecodeEngine(cfg, params, rules, max_slots=1, max_len=64)
    exact._bucket_prompts = False
    outs = []
    for eng in (bucketed, exact):
        req = Request(rid="r", prompt=prompt.copy(), max_new=6)
        eng.run([req])
        outs.append(req.generated)
    assert outs[0] == outs[1]


def test_resume_splice_reuses_one_program_across_slots_and_engines(
        setup_engine):
    from repro.serving import engine as engine_mod
    from repro.serving.engine import DecodeEngine, Request
    cfg, rules, params = setup_engine
    clock = VirtualClock()
    fab = ShardedTieredStore(2, policy_factory=_pinned, clock=clock)
    eng0 = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                        store=fab.host_view(0), step_time=1e-3)
    eng1 = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                        store=fab.host_view(1), step_time=1e-3)
    rng = np.random.default_rng(0)
    for i in range(2):
        eng0.admit(Request(rid=f"s{i}", prompt=rng.integers(
            1, cfg.vocab, 5).astype(np.int32)))
    eng0.step()
    eng0.pause("s0")
    eng0.pause("s1")
    clock.advance(1.0)
    # first resume may trace the block-splice program once; the second
    # (different slot) and the cross-host third must reuse it
    eng0.resume("s0")
    base = engine_mod.splice_trace_counts()["block"]
    eng0.resume("s1")                       # second slot, same engine
    eng0.pause("s1")
    state = eng0.export_session("s1")
    eng1.import_session("s1", state)
    clock.advance(1.0)
    eng1.resume("s1")                       # cross-host resume
    assert engine_mod.splice_trace_counts()["block"] == base


# ---------------------------------------------------------------------------
# roofline hook: measured step time with modeled fallback
# ---------------------------------------------------------------------------

def _fake_roofline(tmp_path, arch, shape, bound):
    rec = {"arch": arch, "shape": shape,
           "roofline": {"step_time_bound": bound}}
    (tmp_path / f"{arch}__{shape}__single.json").write_text(
        json.dumps(rec))


def test_measured_step_time_reads_roofline_results(tmp_path):
    _fake_roofline(tmp_path, "gemma-2b", "decode_32k", 3e-3)
    _fake_roofline(tmp_path, "qwen3-moe", "decode_32k", 7e-3)
    (tmp_path / "corrupt__decode_32k__single.json").write_text("{nope")
    assert measured_step_time(
        arch="gemma-2b", results_dir=str(tmp_path)) == 3e-3
    # fleet-wide: the slowest decode bound (conservative lead budget)
    assert measured_step_time(results_dir=str(tmp_path)) == 7e-3
    assert measured_step_time(arch="absent",
                              results_dir=str(tmp_path)) is None


def test_spec_measured_step_time_with_fallback(tmp_path):
    _fake_roofline(tmp_path, "gemma-2b", "decode_32k", 4e-3)
    spec = HierarchySpec(step_time="measured", step_time_fallback=9e-4,
                         roofline_results=str(tmp_path))
    assert spec.resolved_step_time() == 4e-3
    off_hw = dataclasses.replace(spec,
                                 roofline_results=str(tmp_path / "no"))
    assert off_hw.resolved_step_time() == 9e-4
    assert Platform.compile(dataclasses.replace(
        off_hw, policy=PolicyDecl.pinned_flash())).step_time == 9e-4


# ---------------------------------------------------------------------------
# deprecation hygiene: the declarative bench paths are warning-clean
# (the CI gate runs the CLIs under -W error::DeprecationWarning)
# ---------------------------------------------------------------------------

def test_spec_bench_path_is_deprecation_clean():
    spec = HierarchySpec(hosts=(HostDecl(count=2),),
                         policy=PolicyDecl.pinned_flash())
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        multi_host_session_bench("async", spec=spec, n_sessions=2,
                                 rounds=1, kv_bytes=1 << 16,
                                 decode_steps=2, step_time=1e-3, lead=1)
        run_autoscale_bench(n_steps=20, every=5)
