"""Vectorized control-plane tests: batched ring routing, the
array-backed ghost, pow2 sketch padding and the batched SSD service
ladder — each batch path checked value-for-value against its scalar
(or sequential) reference."""
import collections

import numpy as np
import pytest

from repro.autopilot.reuse import ReuseTracker, _ArrayGhost
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import ShardedTieredStore
from repro.runtime.service import SsdQueueModel


# --------------------------------------------------------------- routing
def _mixed_keys(rng, n):
    keys = []
    for i in range(n):
        pick = i % 3
        if pick == 0:
            keys.append(int(rng.integers(0, 1 << 40)))
        elif pick == 1:
            keys.append(f"kv-{int(rng.integers(0, 1 << 20))}")
        else:
            keys.append(("kv", f"s{int(rng.integers(0, 9999)):04d}"))
    return keys


def test_owner_batch_matches_scalar_weighted_ring():
    fab = ShardedTieredStore(5, weights=[1.0, 2.0, 1.0, 3.0, 1.0],
                             clock=VirtualClock())
    keys = _mixed_keys(np.random.default_rng(0), 600)
    scalar = np.array([fab.owner(k) for k in keys])
    assert np.array_equal(fab.owner_batch(keys), scalar)


def test_owner_batch_digests_survive_ring_changes():
    fab = ShardedTieredStore(3, clock=VirtualClock())
    keys = _mixed_keys(np.random.default_rng(1), 400)
    digests = fab.key_digest_batch(keys)
    assert np.array_equal(fab.owner_batch(digests=digests),
                          [fab.owner(k) for k in keys])
    fab.add_host()
    # same digests, new ring: still identical to the scalar path
    assert np.array_equal(fab.owner_batch(digests=digests),
                          [fab.owner(k) for k in keys])


def test_owner_batch_needs_keys_or_digests():
    fab = ShardedTieredStore(2, clock=VirtualClock())
    with pytest.raises(ValueError):
        fab.owner_batch()


# ----------------------------------------------------------------- ghost
class _SequentialGhost:
    """The old element-at-a-time OrderedDict ghost, as an oracle."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.d = collections.OrderedDict()

    def touch(self, key, now):
        last = self.d.pop(key, None)
        self.d[key] = now
        while len(self.d) > self.capacity:
            self.d.popitem(last=False)
        if last is None:
            return 0.0
        return max(now - last, 1e-9)


def test_array_ghost_matches_sequential_oracle_no_eviction():
    """In the headroom regime (every real config) the array ghost is
    byte-identical to the sequential OrderedDict ghost."""
    g = _ArrayGhost(1 << 16)
    ref = _SequentialGhost(1 << 16)
    rng = np.random.default_rng(2)
    for step in range(300):
        now = 0.1 * (step + 1)
        keys = rng.integers(0, 500, size=rng.integers(1, 40)).tolist()
        got = g.touch_batch(keys, now)
        want = np.array([ref.touch(k, now) for k in keys], np.float32)
        # the oracle cannot see within-batch duplicates as duplicates
        # (it re-touches sequentially) — both measure the 1e-9 floor
        assert np.array_equal(got, want)
        assert len(g) == len(ref.d)
    assert set(ref.d) == {k for k in range(500) if k in g}


def test_array_ghost_duplicate_and_first_touch_semantics():
    g = _ArrayGhost(16)
    iv = g.touch_batch(["a", "a", "b"], 1.0)
    assert iv[0] == 0.0 and iv[1] == np.float32(1e-9) and iv[2] == 0.0
    iv = g.touch_batch(["a"], 3.0)
    assert iv[0] == np.float32(2.0)


def test_array_ghost_fifo_eviction_order():
    """Batch-1 touches reproduce the old per-element FIFO-on-last-touch
    eviction exactly (move-to-end on re-touch)."""
    g = _ArrayGhost(3)
    for i, k in enumerate(("a", "b", "c")):
        g.touch_batch([k], float(i + 1))
    g.touch_batch(["d"], 4.0)                  # a is oldest -> evicted
    assert "a" not in g and g.get("a") is None
    g.touch_batch(["b"], 5.0)                  # b moves to the end
    g.touch_batch(["e"], 6.0)                  # c is now oldest
    assert "c" not in g
    assert "b" in g and "d" in g and "e" in g
    assert len(g) == 3


def test_array_ghost_batch_eviction_keeps_most_recent():
    g = _ArrayGhost(4)
    g.touch_batch(list(range(10)), 1.0)        # one batch over capacity
    assert len(g) == 4
    assert all(k in g for k in (6, 7, 8, 9))   # highest touch sequences


def test_array_ghost_discard_and_grow():
    g = _ArrayGhost(1 << 14)
    keys = [f"k{i}" for i in range(5000)]      # forces _grow past 1024
    g.touch_batch(keys, 1.0)
    assert len(g) == 5000
    g.discard("k42")
    g.discard("k42")                           # idempotent
    assert "k42" not in g and len(g) == 4999
    assert g.touch_batch(["k42"], 2.0)[0] == 0.0   # truly forgotten


def test_tracker_observe_batch_class_array_path():
    """Pre-computed int class ids give the same sketch and intervals as
    the string path."""
    ta = ReuseTracker(ghost_capacity=1 << 12)
    tb = ReuseTracker(ghost_capacity=1 << 12)
    kv_a, obj_a = ta.class_id("kv"), ta.class_id("obj")
    tb.class_id("kv"), tb.class_id("obj")      # same id assignment
    rng = np.random.default_rng(3)
    for step in range(20):
        now = 0.5 * (step + 1)
        keys = rng.integers(0, 200, size=50).tolist()
        cls_int = np.where(np.asarray(keys) < 100, kv_a, obj_a)
        names = ["kv" if k < 100 else "obj" for k in keys]
        iv_a = ta.observe_batch(keys, cls_int.astype(np.int64), now)
        iv_b = tb.observe_batch(keys, names, now)
        assert np.array_equal(iv_a, iv_b)
    assert np.array_equal(ta.hist, tb.hist)
    assert ta.measured == tb.measured


# ---------------------------------------------------------------- sketch
def test_sketch_pow2_padding_result_independent():
    from repro.kernels.reuse_sketch.ops import reuse_sketch_update
    from repro.kernels.reuse_sketch.ref import reference_reuse_sketch

    rng = np.random.default_rng(4)
    hist = np.zeros((4, 16), np.float32)
    for n in (1, 3, 5, 8, 13):
        iv = rng.random(n).astype(np.float32) * 10.0
        cls = rng.integers(0, 4, size=n).astype(np.int32)
        want = reference_reuse_sketch(hist, iv, cls, tau0=1e-3,
                                      decay=0.99)
        for pad in (4, 16, 0):       # different widths, same answer
            got = np.asarray(reuse_sketch_update(
                hist, iv, cls, tau0=1e-3, decay=0.99, batch_pad=pad))
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        hist = want


# --------------------------------------------------------- service ladder
def test_service_total_batch_matches_scalar():
    model = SsdQueueModel.shared()
    depths = [1, 2, 3, 7, 16, 100, 128, 500]   # on-, off-grid, clipped
    for nbytes in (4096, 128 << 10, 1 << 20):
        want = [model.service(nbytes, d).total for d in depths]
        got = model.service_total_batch(nbytes, depths)
        np.testing.assert_allclose(got, want, rtol=1e-12)


# ----------------------------------------------------------- scale replay
def test_scale_replay_deterministic_and_consistent():
    from repro.serving.scale import scale_replay

    kw = dict(n_keys=3000, n_sessions=300, n_steps=6,
              accesses_per_step=400, n_hosts=3, seed=7)
    rec1, _ = scale_replay(**kw)
    rec2, timings = scale_replay(**kw)
    assert rec1 == rec2                        # byte-stable modeled record
    assert rec1["ops_dram_hits"] + rec1["ops_flash_misses"] \
        == rec1["accesses"]
    assert 0.0 <= rec1["hit_rate"] <= 1.0
    assert rec1["total_stall"] > 0.0
    assert set(timings) >= {"digest", "routing", "tracking", "admission",
                            "stall_pricing", "total", "keys_per_sec"}


def test_scale_replay_dedupes_misses_per_step():
    """Regression: one cold key touched 50x in a step queues ONE flash
    fetch — the first touch misses, the 49 repeats are served by the
    in-flight fetch (DRAM hits). The old accounting queued all 50,
    overstating the step's stall by the whole ladder ramp."""
    from repro.runtime.service import SsdQueueModel
    from repro.serving.scale import scale_replay

    l_blk = 128 << 10
    rec, _ = scale_replay(n_keys=100, n_sessions=10, n_hosts=2,
                          l_blk=l_blk, trace=[np.full(50, 7, np.int64)])
    assert rec["ops_flash_misses"] == 1.0
    assert rec["ops_dram_hits"] == 49.0
    assert rec["ops_dram_hits"] + rec["ops_flash_misses"] \
        == rec["accesses"] == 50.0
    # the stall is exactly one depth-1 fetch, not a 50-deep queue
    one_fetch = SsdQueueModel.shared().service(l_blk, 1).total
    assert rec["total_stall"] == pytest.approx(one_fetch)

    # distinct cold keys still queue behind each other (no over-dedupe)
    rec2, _ = scale_replay(n_keys=100, n_sessions=10, n_hosts=2,
                           l_blk=l_blk,
                           trace=[np.arange(4, dtype=np.int64)])
    assert rec2["ops_flash_misses"] == 4.0
    ladder = sum(SsdQueueModel.shared().service(l_blk, d).total
                 for d in (1, 2, 3, 4))
    assert rec2["total_stall"] == pytest.approx(ladder)
    assert rec2["total_stall"] > one_fetch


def test_prior_or_inf_explicit_none_check():
    """Regression: `quantile or np.inf` sent a legitimate 0.0 prior
    (maximally hot class) to infinity (maximally cold) — only a
    missing prior means "assume never reused"."""
    from repro.serving.scale import _prior_or_inf

    assert _prior_or_inf(None) == np.inf
    assert _prior_or_inf(0.0) == 0.0
    assert _prior_or_inf(2.5) == 2.5
