"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU), plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import reference_attention
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import reference_decode_attention
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import reference_rmsnorm
from repro.kernels.ann_topk.ops import ann_topk
from repro.kernels.ann_topk.ref import reference_ann_topk
from repro.kernels.cuckoo_probe.ops import cuckoo_probe, hash_pair
from repro.kernels.cuckoo_probe.ref import reference_cuckoo_probe


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,D", [
    (2, 4, 2, 128, 64),      # GQA
    (1, 8, 1, 256, 128),     # MQA
    (2, 4, 4, 200, 80),      # MHA, ragged seq, odd head_dim
    (1, 2, 2, 384, 112),     # zamba2 head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, KV, S, D, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, S, D), dtype)
    k = _rand(ks[1], (B, KV, S, D), dtype)
    v = _rand(ks[2], (B, KV, S, D), dtype)
    o = flash_attention(q, k, v, causal, None, True)
    r = reference_attention(q, k, v, causal=causal, scale=1 / np.sqrt(D))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        atol=tol, rtol=tol)


def test_flash_attention_grad_matches_reference():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (1, 4, 64, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 64, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 64, 64), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(reference_attention(
            q, k, v, causal=True, scale=1 / 8.0) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,T,D", [
    (4, 8, 2, 1024, 64),
    (2, 8, 8, 600, 128),     # non-divisible T (padded tail)
    (3, 4, 1, 512, 128),
    (1, 16, 16, 96, 64),     # T < block_k
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KV, T, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = _rand(ks[0], (B, H, D), dtype)
    k = _rand(ks[1], (B, KV, T, D), dtype)
    v = _rand(ks[2], (B, KV, T, D), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, T + 1)
    o = decode_attention(q, k, v, lens)
    r = reference_decode_attention(q, k, v, lens, scale=1 / np.sqrt(D))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64, 256), (3, 100, 512), (1, 8, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(jax.random.PRNGKey(2), shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(3), (shape[-1],), jnp.float32)
    o = rmsnorm(x, s)
    r = reference_rmsnorm(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        atol=tol, rtol=tol)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), d=st.sampled_from([8, 128, 384]),
       scale=st.floats(0.1, 10.0))
def test_rmsnorm_output_rms_is_scale(n, d, scale):
    """Property: with unit scale vector * c, output RMS ~= c."""
    x = jax.random.normal(jax.random.PRNGKey(n), (n, d), jnp.float32) \
        * scale
    s = jnp.ones((d,), jnp.float32)
    o = np.asarray(rmsnorm(x, s))
    rms = np.sqrt((o ** 2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-2)


# ---------------------------------------------------------------------------
# ann topk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Q,N,D,k,tile", [
    (64, 1000, 64, 8, 256),
    (100, 2000, 128, 16, 512),
    (16, 300, 32, 4, 128),    # ragged corpus tail
])
def test_ann_topk_sweep(Q, N, D, k, tile):
    qs = jax.random.normal(jax.random.PRNGKey(5), (Q, D), jnp.float32)
    corpus = jax.random.normal(jax.random.PRNGKey(6), (N, D), jnp.float32)
    d, i = ann_topk(qs, corpus, k=k, tile=tile)
    rd, ri = reference_ann_topk(qs, corpus, k=k)
    np.testing.assert_allclose(np.sort(np.asarray(d), axis=1),
                               np.sort(np.asarray(rd), axis=1), atol=1e-3)
    assert (np.sort(np.asarray(i), axis=1)
            == np.sort(np.asarray(ri), axis=1)).mean() > 0.99


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_ann_topk_self_retrieval(seed):
    """Property: a corpus vector queries itself as its own top-1."""
    corpus = jax.random.normal(jax.random.PRNGKey(seed), (257, 32),
                               jnp.float32)
    d, i = ann_topk(corpus[:32], corpus, k=1, tile=64)
    assert (np.asarray(i)[:, 0] == np.arange(32)).all()


# ---------------------------------------------------------------------------
# cuckoo probe
# ---------------------------------------------------------------------------

def _build_table(nb, slots, n_items, seed=0):
    rng = np.random.default_rng(seed)
    bk = np.zeros((nb, slots), np.int32)
    bv = np.zeros((nb, slots), np.int32)
    keys = rng.choice(np.arange(1, 10**6), size=n_items,
                      replace=False).astype(np.int32)
    b1, b2 = (np.asarray(h) for h in hash_pair(jnp.asarray(keys), nb))
    stored = []
    for kk, x1, x2 in zip(keys, b1, b2):
        for b in (x1, x2):
            free = np.where(bk[b] == 0)[0]
            if len(free):
                bk[b, free[0]] = kk
                bv[b, free[0]] = int(kk) % 9973
                stored.append(kk)
                break
    return bk, bv, np.array(stored, np.int32)


@pytest.mark.parametrize("nb,slots,n", [(128, 8, 400), (512, 4, 800)])
def test_cuckoo_probe_sweep(nb, slots, n):
    bk, bv, stored = _build_table(nb, slots, n)
    rng = np.random.default_rng(1)
    miss = rng.integers(2 * 10**6, 3 * 10**6, 64).astype(np.int32)
    probe = np.concatenate([stored[:128], miss])
    f, v = cuckoo_probe(jnp.asarray(probe), jnp.asarray(bk),
                        jnp.asarray(bv))
    rf, rv = reference_cuckoo_probe(
        jnp.asarray(probe), *hash_pair(jnp.asarray(probe), nb),
        jnp.asarray(bk), jnp.asarray(bv))
    assert (np.asarray(f) == np.asarray(rf)).all()
    assert (np.asarray(v) == np.asarray(rv)).all()
    n_stored = min(128, len(stored))
    assert np.asarray(f)[:n_stored].all()
    assert not np.asarray(f)[len(probe) - 64:].any()
