"""Causal trace + metrics plane tests: the Eq. 1 stall ledger's
conservation law, component attribution on every lane, the
array-backed metrics registry (including the grow-past-capacity
regression), byte-stable Perfetto export, and the canonical bench-JSON
emit helper.

The load-bearing invariant: every modeled stalled second lands in
exactly one ledger component, and on a scheduler run

    sum(components) == kv_stall_time + step_time * slot_idle_steps
                    == per_token_stall * tokens

to 1e-9 relative. The attribution tests below construct one scenario
per component (flash service, NIC queueing, incast fan-in, rebalance
interference, gate-miss restores, scheduler idle, DRAM residuals) so a
regression names the queue it came from, not just "stall went up".
"""
import json

import numpy as np
import pytest

from repro.autopilot.gate import EconomicGate
from repro.core.policy import Tier, TieringPolicy
from repro.obs import (COMPONENTS, Counter, Gauge, Histogram,
                       MetricsRegistry, Observability, StallLedger,
                       Tracer, bench_json, canon, write_bench_json)
from repro.obs.ledger import tenant_of_key
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import ShardedTieredStore
from repro.runtime.service import FabricTopology, NetQueueModel
from repro.runtime.tiers import TieredStore

REL_TOL = 1e-9


def _pinned_flash():
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def _rel_err(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1e-30)


# ---------------------------------------------------------------------------
# StallLedger unit behavior
# ---------------------------------------------------------------------------

def test_ledger_components_and_conservation_bookkeeping():
    led = StallLedger()
    led.add("flash_service", 1.5, "prem")
    led.add("scheduler_idle", 0.5)
    led.add("nope", 0.25)                   # unknown -> other
    assert led.totals["other"] == 0.25
    assert led.total() == pytest.approx(2.25)
    d = led.as_dict()
    assert d["total"] == pytest.approx(2.25)
    assert set(COMPONENTS) <= set(d)
    assert d["tenants"]["prem"]["flash_service"] == 1.5
    # zero adds must not materialize tenant rows
    led.add("flash_service", 0.0, "ghost")
    assert "ghost" not in led.tenants


def test_ledger_delta_since_and_reset():
    led = StallLedger()
    led.add("nic_queue", 1.0)
    base = led.snapshot()
    led.add("nic_queue", 0.75)
    led.add("incast", 0.25)
    d = led.delta_since(base)
    assert d["nic_queue"] == pytest.approx(0.75)
    assert d["incast"] == pytest.approx(0.25)
    assert d["flash_service"] == 0.0
    led.reset_stats()
    assert led.total() == 0.0 and led.tenants == {}


def test_tenant_of_key_conventions():
    assert tenant_of_key(("kv", "premium/003")) == "premium"
    assert tenant_of_key(("kv", "bare")) == ""       # no tenant tag
    assert tenant_of_key(("obj", "a/b")) == ""       # not a KV key
    assert tenant_of_key("kv") == ""


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_counter_grows_past_initial_capacity():
    """Regression: `vals[rowof(label)] += v` bound the pre-growth array
    before `_rowof` replaced it, so label #9 raised IndexError."""
    c = Counter("hosts")
    for i in range(40):
        c.inc((f"host{i}",), 2.0)
    for i in range(40):
        assert c.value((f"host{i}",)) == 2.0
    assert len(c.labels()) == 40


def test_gauge_set_grows_and_overwrites():
    g = Gauge("resident")
    for i in range(20):
        g.set((f"h{i}",), float(i))
    g.set(("h3",), 99.0)
    assert g.value(("h3",)) == 99.0
    g.inc(("h3",), 1.0)                    # gauges may accumulate too
    assert g.value(("h3",)) == 100.0


def test_histogram_batch_observe_and_quantiles():
    h = Histogram("stall", n_buckets=24, tau0=1e-6)
    vals = np.full(1000, 1e-3)
    h.observe_batch(vals, ("host0",))
    h.observe(0.0, ("host0",))             # exact zero -> bucket 0
    assert h.count(("host0",)) == 1001
    assert h.sum(("host0",)) == pytest.approx(1.0)
    p50 = h.quantile(0.5, ("host0",))
    assert 1e-3 / 2 <= p50 <= 2e-3         # bucket-center resolution
    assert h.quantile(0.5, ("nolabel",)) is None
    d = h.as_dict()["host0"]
    assert d["count"] == 1001 and d["p99"] >= d["p50"]


def test_registry_register_enforces_protocol():
    reg = MetricsRegistry()

    class Good:
        def snapshot_stats(self):
            return {"x": 1}

        def reset_stats(self):
            pass

    class Bad:
        def snapshot_stats(self):
            return {}

    reg.register("good", Good())
    with pytest.raises(TypeError, match="reset_stats"):
        reg.register("bad", Bad())
    assert reg.components() == ["good"]
    snap = reg.snapshot()
    assert snap["components"]["good"] == {"x": 1}


def test_registry_reset_walks_metrics_and_components():
    reg = MetricsRegistry()
    reg.counter("n").inc(("a",), 5.0)
    reg.gauge("g").set(("a",), 3.0)
    reg.histogram("h").observe(1.0)
    led = StallLedger()
    led.add("other", 1.0)
    reg.register("stall_ledger", led)
    reg.reset()
    assert reg.counter("n").value(("a",)) == 0.0
    assert reg.gauge("g").value(("a",)) == 0.0
    assert reg.histogram("h").count() == 0.0
    assert led.total() == 0.0


# ---------------------------------------------------------------------------
# Canonical bench JSON
# ---------------------------------------------------------------------------

def test_canon_folds_numpy_and_nonfinite():
    obj = {"a": np.float64(1.5), "n": np.int32(3),
            "arr": np.arange(3), "inf": float("inf"),
            "nan": float("nan"), "neg": float("-inf")}
    c = canon(obj)
    assert c["a"] == 1.5 and c["n"] == 3 and c["arr"] == [0, 1, 2]
    assert c["inf"] == "inf" and c["neg"] == "-inf" and c["nan"] == "nan"
    json.dumps(c)                          # round-trips without error


def test_bench_json_bytes_independent_of_insertion_order(tmp_path):
    a = {"z": 1, "a": {"y": 2.0, "x": [3, {"k": 4}]}}
    b = {"a": {"x": [3, {"k": 4}], "y": 2.0}, "z": 1}
    assert bench_json(a) == bench_json(b)
    out = tmp_path / "r.json"
    js = write_bench_json(a, out=out, echo=False)
    assert out.read_text() == js + "\n"
    assert json.loads(js) == json.loads(bench_json(b))


# ---------------------------------------------------------------------------
# Tracer / Perfetto export
# ---------------------------------------------------------------------------

def test_tracer_chrome_json_shape():
    t = Tracer()
    track = t.track("host0", "FLASH")
    t.complete(track, "fetch", 1.0, 0.5, cat="transfer",
               args={"key": "k"})
    t.instant(t.track("scheduler", "policy"), "admit_tier", 1.25,
              cat="policy", args={"tau_be": 5.0})
    fid = t.flow_id(("kv", "s0"))
    t.flow_start(track, "session", 1.0, fid)
    t.flow_end(track, "session", 1.5, fid)
    doc = json.loads(t.to_chrome_json())
    evs = doc["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"X", "i", "M", "s", "f"} <= phases
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == pytest.approx(1.0e6)    # seconds -> microseconds
    assert x["dur"] == pytest.approx(0.5e6)
    assert doc["otherData"]["dropped_events"] == 0
    # process/thread metadata names both tracks
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"host0", "scheduler"} <= names


def test_tracer_caps_events_and_counts_drops():
    t = Tracer(max_events=4)
    track = t.track("h", "lane")
    for i in range(10):
        t.instant(track, f"e{i}", float(i))
    # 2 metadata events (track names) + 2 instants fit; 8 drop
    assert len(t) == 4 and t.dropped == 8
    doc = json.loads(t.to_chrome_json())
    assert doc["otherData"]["dropped_events"] == 8
    # metadata events bypass the cap: the track stays named
    assert any(e["ph"] == "M" for e in doc["traceEvents"])


def test_flamegraph_aggregates_span_time():
    t = Tracer()
    tr = t.track("host0", "FLASH")
    t.complete(tr, "fetch", 0.0, 1.0)
    t.complete(tr, "fetch", 2.0, 0.5)
    t.complete(tr, "write", 2.0, 0.25)
    lines = t.flamegraph().splitlines()
    assert "host0;FLASH;fetch 1500000" in lines[0]  # µs, sorted desc


# ---------------------------------------------------------------------------
# Ledger attribution: one scenario per component
# ---------------------------------------------------------------------------

def test_flash_service_attribution_after_demotion():
    """Repeat accesses past tau_be demote the key to flash; the next
    fetch's seconds land in flash_service (tenant-attributed)."""
    clock = VirtualClock()
    store = TieredStore(_pinned_flash(), clock=clock)
    key = ("kv", "prem/000")
    store.put(key, np.zeros(4096, np.float32), tier=Tier.DRAM)
    clock.advance(1.0)
    store.get(key)                       # DRAM fetch -> "other", demotes
    assert store.ledger.totals["other"] > 0
    clock.advance(1.0)
    store.get(key)                       # now resident on flash
    led = store.ledger
    assert led.totals["flash_service"] > 0
    assert led.tenants["prem"]["flash_service"] == pytest.approx(
        led.totals["flash_service"])
    # media cost, not a policy cost: no gate in play
    assert led.totals["gate_miss_restore"] == 0.0


def test_gate_miss_restore_attribution():
    """A key the EconomicGate priced out of DRAM restores from flash;
    those seconds are a policy cost (gate_miss_restore), distinct from
    an honestly-cold flash_service fetch."""
    obs = Observability()
    clock = VirtualClock()
    gate = EconomicGate(tau_hot=1e-4, tau_be=1e-3)
    store = TieredStore(gate, clock=clock, obs=obs, label="host0")
    key = ("kv", "prem/000")
    blob = np.zeros(4096, np.float32)
    store.put(key, blob)
    clock.advance(1.0)
    store.get(key)                       # measured gap 1 s >> tau_be
    clock.advance(1.0)
    store.put(key, blob)                 # re-put: priced straight out
    assert store.tier_of(key) == Tier.FLASH
    assert gate.priced_out(key)
    clock.advance(1.0)
    store.get(key)
    assert obs.ledger.totals["gate_miss_restore"] > 0
    assert obs.ledger.totals["flash_service"] == 0.0
    assert "prem" in obs.ledger.tenants


def _quiet_fabric(n_hosts, clock, obs, **kw):
    return ShardedTieredStore(
        n_hosts, clock=clock, obs=obs,
        policy_factory=lambda h: TieringPolicy(
            tau_hot=1e-12, tau_be=1e9, ema_alpha=1.0),
        **kw)


def test_nic_queue_attribution_on_remote_fetch():
    obs = Observability()
    clock = VirtualClock()
    fab = _quiet_fabric(4, clock, obs)
    key = ("kv", "t0/000")
    own = fab.owner(key)
    fab.put(key, np.zeros(1 << 16, np.float32), from_host=own)
    clock.advance(1.0)
    fab.get(key, from_host=(own + 1) % 4)
    assert obs.ledger.totals["nic_queue"] > 0
    assert obs.ledger.totals["incast"] == 0.0    # no topology model


def test_incast_attribution_under_fan_in():
    """With a topology model, many senders fanning into one host divide
    its ingress bandwidth; the ledger splits those NIC seconds into the
    fan-in share (incast) vs the base wire time (nic_queue)."""
    obs = Observability()
    clock = VirtualClock()
    topo = FabricTopology(hosts_per_rack=2, incast_degree=2)
    fab = _quiet_fabric(4, clock, obs,
                        net_model=NetQueueModel(topology=topo))
    blob = np.zeros(1 << 18, np.float32)
    keys = [("kv", f"t/{i:03d}") for i in range(12)]
    for k in keys:
        fab.put(k, blob, from_host=fab.owner(k))
    clock.advance(1.0)
    dst = 0
    pfs = [fab.get_async(k, from_host=dst) for k in keys
           if fab.owner(k) != dst]
    assert max(pf.nic_tr.incast_frac for pf in pfs) > 0
    # wait the deepest fan-in transfer first so its stall is real
    # (waited last, it would have completed in the background)
    for pf in sorted(pfs, key=lambda p: -p.nic_tr.incast_frac):
        pf.wait()
    assert obs.ledger.totals["incast"] > 0
    assert obs.ledger.totals["nic_queue"] > 0


def test_interference_attribution_behind_rebalance():
    """A fetch queued behind a host-join rebalance stream charges its
    queue wait to interference, not the lane's own service."""
    obs = Observability()
    clock = VirtualClock()
    fab = _quiet_fabric(2, clock, obs)
    blob = np.zeros(1 << 16, np.float32)
    for i in range(24):
        k = ("kv", f"a/{i:03d}")
        fab.put(k, blob, from_host=fab.owner(k))
    clock.advance(1.0)
    fab.add_host()                        # rebalance streams kick off
    k0 = ("kv", "a/000")
    fab.get(k0, from_host=fab.owner(k0))
    assert obs.ledger.totals["interference"] > 0


def _fabric_stall_sum(fab) -> float:
    """Total stall the fabric's runtimes materialized (every lane of
    every live + retired host store and NIC) — what the shared ledger
    must conserve for non-scheduler runs."""
    total = 0.0
    for store in fab._all_stores():
        total += sum(q.stall_time for q in store.runtime.qstats.values())
    for nic in fab._all_nics():
        total += sum(q.stall_time for q in nic.qstats.values())
    return total


def test_failover_degraded_reads_conserve_ledger():
    """Unplanned host failure: in-flight fetches fall back to degraded
    reads from a surviving replica; every stalled second still lands in
    the one shared ledger (conservation against the lane stats)."""
    obs = Observability()
    clock = VirtualClock()
    fab = _quiet_fabric(3, clock, obs)
    blob = np.zeros(1 << 16, np.float32)
    keys = [("kv", f"s/{i:03d}") for i in range(12)]
    for k in keys:
        fab.put(k, blob, from_host=fab.owner(k), replicas=2)
    clock.advance(1.0)
    victim = fab.owner(keys[0])

    def non_holder(k):
        # force the remote composition: fetch from the one host (3
        # hosts, 2 replicas) that does not hold a copy
        return next(h for h in fab.host_ids if h not in fab.holders(k))

    pfs = [fab.get_async(k, from_host=non_holder(k)) for k in keys]
    fab.fail_host(victim)
    got = 0
    for pf in pfs:
        try:
            pf.wait()
            got += 1
        except KeyError:
            pass                          # sole copy died with the host
    assert got == len(keys)               # replicas=2 saved every key
    assert obs.metrics.counter("degraded_reads").as_dict()
    lane_stall = _fabric_stall_sum(fab)
    assert lane_stall > 0
    assert _rel_err(obs.ledger.total(), lane_stall) <= REL_TOL


# ---------------------------------------------------------------------------
# Conservation on scheduler scenario replays (needs the jax model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rules, params


@pytest.mark.parametrize("scenario", ["zipf", "diurnal", "multi_tenant"])
def test_scheduler_conservation_on_scenarios(setup, scenario):
    """The acceptance bar: on a full continuous-batching replay the
    ledger total equals both stall definitions to 1e-9 relative."""
    from repro.serving.engine import DecodeEngine
    from repro.serving.scheduler import (ContinuousScheduler,
                                         jobs_from_trace)
    cfg, rules, params = setup
    clock = VirtualClock()
    store = TieredStore(_pinned_flash(), clock=clock)
    eng = DecodeEngine(cfg, params, rules, max_slots=4, max_len=64,
                       store=store, clock=clock, step_time=0.25)
    sched = ContinuousScheduler(eng, pause_idle_steps=0, prefetch_lead=0)
    jobs = jobs_from_trace(scenario, n_jobs=6, n_turns=3,
                           tokens_per_turn=5, horizon=72)
    report = sched.run(jobs)
    led = report["stall_ledger"]
    assert set(led) == set(COMPONENTS) | {"total"}
    rhs = eng.kv_stall_time + eng.step_time * report["slot_idle_steps"]
    assert _rel_err(led["total"], rhs) <= REL_TOL
    assert _rel_err(led["total"], report["per_token_stall"]
                    * max(report["tokens"], 1)) <= REL_TOL
    assert led["scheduler_idle"] > 0
    # restores did stall (the scenario is not prefetch-hidden)
    assert led["total"] - led["scheduler_idle"] > 0


def test_scheduler_ledger_is_delta_on_shared_fleet_ledger(setup):
    """A scheduler built on a store whose ledger already carries stall
    reports only its own slice (delta since construction)."""
    from repro.serving.engine import DecodeEngine
    from repro.serving.scheduler import (ContinuousScheduler,
                                         jobs_from_trace)
    cfg, rules, params = setup
    clock = VirtualClock()
    store = TieredStore(_pinned_flash(), clock=clock)
    store.ledger.add("flash_service", 123.0, "past")   # pre-existing
    eng = DecodeEngine(cfg, params, rules, max_slots=4, max_len=64,
                       store=store, clock=clock, step_time=0.25)
    sched = ContinuousScheduler(eng, pause_idle_steps=0, prefetch_lead=0)
    report = sched.run(jobs_from_trace("zipf", n_jobs=3, n_turns=2,
                                       tokens_per_turn=4, horizon=24))
    rhs = eng.kv_stall_time + eng.step_time * report["slot_idle_steps"]
    assert _rel_err(report["stall_ledger"]["total"], rhs) <= REL_TOL


# ---------------------------------------------------------------------------
# Platform integration: ObservabilityDecl -> compiled plane
# ---------------------------------------------------------------------------

def _obs_spec(trace: bool):
    from repro.platform import (HierarchySpec, HostDecl,
                                ObservabilityDecl, PolicyDecl, TierDecl)
    return HierarchySpec(
        hosts=(HostDecl(count=2,
                        tiers={"dram": TierDecl(1 << 22, 45e9, 5e-7)}),),
        policy=PolicyDecl.economic(l_blk=4096),
        observability=ObservabilityDecl(trace=trace))


def test_observability_decl_validates_and_roundtrips():
    from repro.platform import HierarchySpec, ObservabilityDecl
    with pytest.raises(ValueError, match="max_events"):
        ObservabilityDecl(max_events=0).validate()
    spec = _obs_spec(trace=True)
    spec.validate()
    again = HierarchySpec.from_json(spec.to_json())
    assert again.observability == spec.observability
    assert again == spec


def test_platform_compiles_shared_observability_plane():
    from repro.platform.compiler import Platform
    platform = Platform.compile(_obs_spec(trace=True))
    assert platform.tracer is not None
    assert platform.metrics is not None
    # one ledger shared fleet-wide: the host view's IS the platform's
    hv = platform.fabric.host_view(0)
    assert hv.ledger is platform.ledger
    assert "fabric" in platform.metrics.components()
    assert "stall_ledger" in platform.metrics.components()
    key = ("kv", "t/000")
    platform.fabric.put(key, np.zeros(1024, np.float32),
                        from_host=platform.fabric.owner(key))
    snap = platform.snapshot_stats()
    assert "fabric" in snap["components"]
    platform.reset_stats()
    assert platform.ledger.total() == 0.0


def test_trace_export_is_byte_identical_across_runs():
    """Two identical runs on the virtual clock must export identical
    Perfetto bytes — the CI double-run gate in unit form."""
    from repro.platform.compiler import Platform

    def one_run() -> str:
        platform = Platform.compile(_obs_spec(trace=True))
        fab = platform.fabric
        blob = np.zeros(4096, np.float32)
        for i in range(8):
            k = ("kv", f"t/{i:03d}")
            fab.put(k, blob, from_host=fab.owner(k))
        platform.clock.advance(1.0)
        for i in range(8):
            k = ("kv", f"t/{i:03d}")
            fab.get(k, from_host=(fab.owner(k) + 1) % fab.n_hosts)
        fab.drain()
        return platform.tracer.to_chrome_json()

    assert one_run() == one_run()


def test_scale_replay_record_invariant_under_metrics(tmp_path):
    """The 1M-key replay's modeled record must be byte-identical with
    the metrics plane on and off — observing must never perturb."""
    from repro.serving.scale import scale_replay
    kw = dict(n_keys=2000, n_sessions=400, n_steps=6,
              accesses_per_step=500, n_hosts=2, seed=3)
    rec_off, t_off = scale_replay(**kw, obs=None)
    obs = Observability()
    rec_on, t_on = scale_replay(**kw, obs=obs)
    assert bench_json(rec_off) == bench_json(rec_on)
    assert "metrics" in t_on and t_on["metrics"] >= 0.0
    assert obs.metrics.counter("scale_accesses").value() \
        == rec_on["accesses"]
    # the replay's modeled stall lands in the ledger's flash component
    assert obs.ledger.totals["flash_service"] == pytest.approx(
        rec_on["total_stall"])
