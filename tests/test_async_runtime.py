"""Deterministic virtual-clock tests for the async queueing-aware tiering
runtime: clock injection, queue-depth-dependent flash service, promotion/
demotion hysteresis under the runtime clock, async prefetch overlap
(decode never blocks when the lead covers the fetch), DecodeEngine
pause/resume through the flash tier, expert streaming, and the timed
KV store."""
import numpy as np
import pytest

from repro.core.policy import Tier, TieringPolicy
from repro.kvstore.tiered import TimedCuckooStore
from repro.runtime.async_engine import AsyncTierRuntime
from repro.runtime.clock import (CallableClock, VirtualClock, WallClock,
                                 ensure_clock)
from repro.runtime.service import FixedLatencyModel, SsdQueueModel
from repro.runtime.tiers import TierSpec, TieredStore
from repro.serving.bench import compare, multi_turn_session_bench
from repro.tiering.expert_store import ExpertStore


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------

def test_virtual_clock_semantics():
    c = VirtualClock()
    assert c.now() == 0.0
    c.advance(1.5)
    c.advance_to(1.0)                 # never goes backwards
    assert c.now() == 1.5
    assert c() == 1.5                 # legacy callable form
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_ensure_clock_normalizes():
    assert isinstance(ensure_clock(None), VirtualClock)
    wall = WallClock()
    assert ensure_clock(wall) is wall
    box = {"t": 3.0}
    cc = ensure_clock(lambda: box["t"])
    assert isinstance(cc, CallableClock)
    assert cc.now() == 3.0
    box["t"] = 4.0
    assert cc.advance(10.0) == 4.0    # externally-driven: advance no-op


# ---------------------------------------------------------------------------
# queueing-aware service
# ---------------------------------------------------------------------------

def test_flash_latency_derives_from_ssdsim_and_varies_with_depth():
    model = SsdQueueModel.shared()
    cal = model.calibration()
    # deeper queues: strictly more device throughput, more latency
    iops = [cal[d][0] for d in sorted(cal)]
    assert iops == sorted(iops) and iops[-1] > 2 * iops[0]
    s1 = model.service(1 << 20, queue_depth=1)
    s64 = model.service(1 << 20, queue_depth=64)
    assert s1.occupancy > s64.occupancy      # batching pays
    assert s64.latency >= s1.latency         # but each op waits longer


def test_runtime_fetches_queue_and_overlap():
    rt = AsyncTierRuntime(clock=VirtualClock())
    a = rt.submit(Tier.FLASH, "a", 1 << 20)
    b = rt.submit(Tier.FLASH, "b", 1 << 20)
    # occupancies serialize: b cannot finish before a's occupancy ends
    assert b.start_t >= a.start_t
    assert b.done_t > a.done_t
    assert rt.qstats[Tier.FLASH].miss_under_miss == 1
    # waiting on b advances the virtual clock exactly to completion
    stall = rt.wait(b)
    assert rt.now() == pytest.approx(b.done_t)
    assert stall == pytest.approx(b.done_t - b.issue_t)
    # a is already done: zero residual stall
    assert rt.wait(a) == 0.0


def test_fetch_time_grows_with_queue_depth():
    """The same 4MiB fetch takes longer issued behind a deep queue —
    the queueing effect the seed's fixed-latency model could not show."""
    def fetch_time(n_ahead):
        rt = AsyncTierRuntime(clock=VirtualClock())
        for i in range(n_ahead):
            rt.submit(Tier.FLASH, f"bg{i}", 4 << 20)
        tr = rt.submit(Tier.FLASH, "probe", 4 << 20)
        return rt.wait(tr)
    t0, t8 = fetch_time(0), fetch_time(8)
    assert t8 > 2 * t0


# ---------------------------------------------------------------------------
# store on the runtime
# ---------------------------------------------------------------------------

def _store(tau_hot=1.0, tau_be=10.0):
    clock = VirtualClock()
    pol = TieringPolicy(tau_hot=tau_hot, tau_be=tau_be, hysteresis=0.0,
                        ema_alpha=1.0)
    store = TieredStore(pol, specs={
        Tier.HBM: TierSpec(2**20, 819e9, 1e-7),
        Tier.DRAM: TierSpec(10 * 2**20, 45e9, 5e-7),
        Tier.FLASH: TierSpec(2**40, 7e9, 2e-5),
    }, clock=clock)
    return store, clock


def test_promotion_demotion_hysteresis_on_virtual_clock():
    pol = TieringPolicy(tau_hot=1.0, tau_be=10.0, hysteresis=0.5,
                        ema_alpha=1.0)
    clock = VirtualClock()
    store = TieredStore(pol, clock=clock)
    store.put("x", np.ones(256, np.float32))
    # interval 11s: beyond tau_be but inside the 1.5x hysteresis band
    clock.advance(11.0)
    store.get("x")
    assert store.tier_of("x") == Tier.DRAM
    # interval 30s: crosses the band -> demoted to flash
    clock.advance(30.0)
    store.get("x")
    assert store.tier_of("x") == Tier.FLASH
    # fast reuse inside tau_be/1.5 -> promoted back
    for _ in range(3):
        clock.advance(0.5)
        store.get("x")
    assert store.tier_of("x") < Tier.FLASH
    assert store.stats[Tier.FLASH].demotions == 1


def test_sync_get_blocks_clock_for_queueing_time():
    store, clock = _store()
    store.put("k", np.ones(1 << 18, np.float32), tier=Tier.FLASH)  # 1MiB
    t0 = clock.now()
    store.get("k")
    elapsed = clock.now() - t0
    assert elapsed > 0.0
    assert store.stats[Tier.FLASH].stall_time == pytest.approx(elapsed)


def test_async_prefetch_overlap_eliminates_stall():
    """Decode never blocks when the prefetch lead >= the fetch latency."""
    store, clock = _store()
    store.put("kv", np.ones(1 << 18, np.float32), tier=Tier.FLASH)
    # measure the blocking fetch time on an identical store first
    probe, pclock = _store()
    probe.put("kv", np.ones(1 << 18, np.float32), tier=Tier.FLASH)
    t0 = pclock.now()
    probe.get("kv")
    fetch_time = pclock.now() - t0

    pf = store.get_async("kv")
    store.runtime.advance(fetch_time * 1.01)   # modeled decode compute
    t1 = clock.now()
    pf.wait()
    assert clock.now() == t1                   # zero residual stall
    assert store.stats[Tier.FLASH].prefetch_hits == 1
    assert store.stats[Tier.FLASH].stall_time == 0.0


def test_async_prefetch_short_lead_blocks_only_remainder():
    store, clock = _store()
    store.put("kv", np.ones(1 << 18, np.float32), tier=Tier.FLASH)
    pf = store.get_async("kv")
    full = pf.transfer.done_t - pf.transfer.issue_t
    store.runtime.advance(full / 2)
    t1 = clock.now()
    pf.wait()
    residual = clock.now() - t1
    assert 0 < residual < full
    assert store.stats[Tier.FLASH].prefetch_late == 1


# ---------------------------------------------------------------------------
# expert streaming + timed kv store on the shared engine
# ---------------------------------------------------------------------------

def test_expert_prefetch_streams_behind_compute():
    pol = TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)
    es = ExpertStore(n_layers=1, n_experts=4, policy=pol)
    w = np.ones((64, 64), np.float32)
    for e in range(4):
        es.store.put((0, e), w, tier=Tier.FLASH)
    assert es.prefetch_experts(0, [1, 2]) == 2
    assert es.prefetch_experts(0, [1]) == 0        # idempotent
    es.store.runtime.advance(1.0)                  # a layer of compute
    t0 = es.clock.now()
    out = es.fetch_expert(0, 1)
    assert es.clock.now() == t0                    # overlapped: no stall
    np.testing.assert_array_equal(out, w)


def test_timed_kvstore_put_get_through_wrapper():
    """WAL puts charge DRAM, probes charge flash, cache hits charge DRAM
    — on a bare runtime (no specs), which must carry default models."""
    s = TimedCuckooStore(128, slots=8, dram_cache_items=16, wal_limit=4)
    for k in range(1, 9):
        s.put(k, k * 2)               # triggers WAL flushes (limit 4)
    s.flush()
    assert s.get(3) == 6              # flash probe
    t0 = s.clock.now()
    assert s.get(3) == 6              # now a DRAM cache hit
    assert s.clock.now() > t0         # still charged (DRAM service)
    assert s.get(9999) is None
    assert s.runtime.qstats[Tier.DRAM].submitted >= 9
    assert s.runtime.qstats[Tier.FLASH].submitted > 0


def test_timed_kvstore_batched_gets_beat_serial():
    def build():
        s = TimedCuckooStore(256, slots=8, wal_limit=1 << 30, seed=0)
        for k in range(1, 201):
            s.inner.put(k, k * 3)
        s.inner.flush()
        return s
    serial = build()
    t0 = serial.clock.now()
    for k in range(1, 101):
        serial.get(k)
    t_serial = serial.clock.now() - t0

    batched = build()
    t0 = batched.clock.now()
    vals = batched.get_many(range(1, 101))
    t_batched = batched.clock.now() - t0
    assert vals == [k * 3 for k in range(1, 101)]
    assert t_batched < t_serial / 2
    assert batched.runtime.qstats[Tier.FLASH].miss_under_miss > 0


# ---------------------------------------------------------------------------
# serving: engine round-trip through flash + modeled benchmark
# ---------------------------------------------------------------------------

def test_engine_pause_resume_through_flash_tier():
    """Full DecodeEngine round-trip where the paused KV block actually
    sits on the flash tier and resume goes through the async path."""
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    from repro.serving.engine import DecodeEngine, Request

    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    clock = VirtualClock()
    # tau_be tiny -> the paused KV block demotes to flash on first touch
    eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                       policy=TieringPolicy(tau_hot=1e-12, tau_be=1e-9,
                                            hysteresis=0.0, ema_alpha=1.0),
                       clock=clock, step_time=1e-3)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab, 6).astype(np.int32)
    req = Request(rid="s", prompt=prompt, max_new=8)
    eng.admit(req)
    for _ in range(3):
        eng.step()
    eng.pause("s")
    clock.advance(1.0)
    eng.store.get(("kv", "s"))               # touch -> demote to flash
    assert eng.store.tier_of(("kv", "s")) == Tier.FLASH
    eng.prefetch("s")
    clock.advance(1.0)                       # decode elsewhere overlaps
    stall_before = eng.kv_stall_time
    eng.resume("s")
    assert eng.kv_stall_time == stall_before     # prefetch covered it
    while not req.done:
        eng.step()
    assert len(req.generated) == 8


def test_async_benchmark_beats_sync_per_token_stall():
    r = compare(n_sessions=8, rounds=2, kv_bytes=1 << 20,
                decode_steps=16, step_time=2e-3, lead=8)
    assert r["async"]["per_token_stall"] < r["sync"]["per_token_stall"]
    assert r["async"]["prefetch_hits"] > 0
    # identical token counts -> a fair comparison
    assert r["async"]["tokens"] == r["sync"]["tokens"]


def test_benchmark_deterministic():
    a = multi_turn_session_bench("async", n_sessions=4, rounds=1,
                                 kv_bytes=1 << 20, decode_steps=8,
                                 step_time=1e-3, lead=4)
    b = multi_turn_session_bench("async", n_sessions=4, rounds=1,
                                 kv_bytes=1 << 20, decode_steps=8,
                                 step_time=1e-3, lead=4)
    assert a == b
