"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and finite values (assignment requirement)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs import shapes as shp
from repro.models import model as M
from repro.parallel.sharding import single_device_rules
from repro.train.step import TrainConfig, init_state, train_step


@pytest.fixture(scope="module")
def rules():
    return single_device_rules()


TCFG = TrainConfig()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rules):
    cfg = get_config(arch, reduced=True)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, TCFG)
    batch = shp.concrete_batch(cfg, batch=2, seq=32)
    step = jax.jit(functools.partial(train_step, cfg=cfg, rules=rules,
                                     tcfg=TCFG))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.sum(jnp.abs(
            p.astype(jnp.float32) - q.astype(jnp.float32)))),
            state["params"], new_state["params"]))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch, rules):
    cfg = get_config(arch, reduced=True)
    params, _ = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = shp.concrete_batch(cfg, batch=2, seq=16)
    logits, aux = M.forward(params, cfg, rules, batch, remat=False)
    S = 16
    assert logits.shape == (2, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_loss_decreases(rules):
    """A few steps of training on repeated data must reduce the loss."""
    cfg = get_config("deepseek-7b", reduced=True)
    tcfg = TrainConfig()
    state, _ = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = shp.concrete_batch(cfg, batch=4, seq=32)
    step = jax.jit(functools.partial(train_step, cfg=cfg, rules=rules,
                                     tcfg=tcfg))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_param_count_analytic_matches_actual():
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(params))
        predicted = cfg.param_count()
        # analytic count ignores small per-block biases/gates on recurrent
        # archs; must agree within 12%
        assert abs(actual - predicted) / actual < 0.12, \
            (arch, actual, predicted)


def test_full_configs_match_advertised_sizes():
    expect = {
        "qwen3-moe-235b-a22b": (235e9, 22e9),
        "llama4-maverick-400b-a17b": (400e9, 17e9),
        "deepseek-7b": (7e9, 7e9),
        "granite-20b": (20e9, 20e9),
        "gemma-2b": (2.5e9, 2.5e9),
        "mistral-nemo-12b": (12e9, 12e9),
        "zamba2-7b": (7e9, 7e9),
    }
    for arch, (total, active) in expect.items():
        cfg = get_config(arch)
        assert abs(cfg.param_count() - total) / total < 0.18, arch
        assert abs(cfg.active_param_count() - active) / active < 0.18, arch


def test_long_context_eligibility():
    subq = {a for a in ARCHS if get_config(a).sub_quadratic}
    assert subq == {"xlstm-350m", "zamba2-7b"}
    for a in ARCHS:
        cfg = get_config(a)
        reason = shp.skip_reason(cfg, shp.SHAPES["long_500k"])
        assert (reason is None) == (a in subq)
