"""int8 quantized KV cache: serving-path equivalence within quantization
tolerance, exact dequant round-trip properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models.attention import _quantize_kv
from repro.parallel.sharding import single_device_rules


@pytest.fixture(scope="module")
def rules():
    return single_device_rules()


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 16, 64),
                          jnp.float32) * 3.0
    q, s = _quantize_kv(x)
    assert q.dtype == jnp.int8
    deq = q.astype(jnp.float32) * s.astype(jnp.float32)
    # error bounded by one quantization step per row
    step = np.asarray(s, np.float32)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert (err <= step + 1e-5).all()


@pytest.mark.parametrize("arch", ["gemma-2b", "whisper-medium",
                                  "zamba2-7b"])
def test_int8_decode_close_to_fp_reference(arch, rules):
    """Prefill + decode with the int8 cache tracks the full-precision
    forward within a small relative logit error (KV states quantized,
    recurrent states untouched)."""
    cfg = get_config(arch, reduced=True)
    B, S, S0 = 2, 12, 6
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.d_model),
            jnp.float32) * 0.1
    ref, _ = M.forward(params, cfg, rules, batch,
                       compute_dtype=jnp.float32, remat=False)
    scale = float(jnp.max(jnp.abs(ref)))

    cache = M.init_cache(cfg, B, S, dtype=jnp.int8)
    cache, lp = M.prefill(params, cfg, rules,
                          dict(batch, tokens=toks[:, :S0]), cache,
                          compute_dtype=jnp.float32)
    errs = [float(jnp.max(jnp.abs(lp - ref[:, S0 - 1])))]
    for t in range(S0, S):
        cache, ld = M.decode_step(params, cfg, rules, toks[:, t:t + 1],
                                  cache, jnp.asarray(t, jnp.int32),
                                  compute_dtype=jnp.float32)
        errs.append(float(jnp.max(jnp.abs(ld - ref[:, t]))))
    assert max(errs) / scale < 0.05, (arch, max(errs), scale)


def test_int8_cache_halves_kv_bytes():
    cfg = get_config("gemma-2b", reduced=True)
    c16 = M.init_cache(cfg, 2, 64, dtype=jnp.bfloat16)
    c8 = M.init_cache(cfg, 2, 64, dtype=jnp.int8)
    b16 = sum(x.nbytes for x in jax.tree.leaves(c16))
    b8 = sum(x.nbytes for x in jax.tree.leaves(c8))
    assert b8 < 0.6 * b16          # ~0.53x (int8 + bf16 scales)
