"""Async-prefetch serving benchmark (paper §VII-A session workload).

Compares modeled per-token stall of the seed's synchronous KV restore
against the async queueing-aware runtime's prefetch path, on the same
multi-turn session workload and virtual clock.

  PYTHONPATH=src python benchmarks/serving_async.py
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.obs import write_bench_json  # noqa: E402
from repro.serving.bench import compare  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--kv-mib", type=float, default=2.0)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--step-time-ms", type=float, default=2.0)
    ap.add_argument("--lead", type=int, default=8,
                    help="prefetch lead in decode steps")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write the canonical JSON report here "
                         "(stdout keeps the human table)")
    args = ap.parse_args()

    params = dict(n_sessions=args.sessions, rounds=args.rounds,
                  kv_bytes=int(args.kv_mib * 2**20),
                  decode_steps=args.decode_steps,
                  step_time=args.step_time_ms * 1e-3, lead=args.lead)
    r = compare(**params)
    if args.out:
        write_bench_json({"params": params, **r}, out=args.out,
                         echo=False)
    print(f"{'mode':8s} {'stall/token':>12s} {'total stall':>12s} "
          f"{'makespan':>10s} {'pf hit':>7s} {'pf late':>8s} {'MuM':>5s}")
    for mode in ("sync", "async"):
        d = r[mode]
        print(f"{mode:8s} {d['per_token_stall']*1e6:10.1f}us "
              f"{d['total_stall']*1e3:10.2f}ms "
              f"{d['makespan']*1e3:8.1f}ms "
              f"{int(d['prefetch_hits']):7d} {int(d['prefetch_late']):8d} "
              f"{int(d['miss_under_miss']):5d}")
    speedup = r["sync"]["per_token_stall"] / max(
        r["async"]["per_token_stall"], 1e-12)
    print(f"\nasync prefetch cuts modeled per-token stall "
          f"{speedup:.1f}x on the multi-turn session workload")


if __name__ == "__main__":
    main()
