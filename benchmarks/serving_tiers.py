"""Fourth-tier benchmark: gpu_flash + pool arms vs the 3-tier baseline.

Replays two declared scenario packs through four arms of the same
platform spec and prices each run with the fleet-shared normalized
rates (see `repro.serving.tiers`):

  * ``moe_scan``  — MoE-heavy decodes + a cold-scan tenant whose think
    gaps sit beyond every DRAM band. Its resumes pay the flash path in
    every arm, so the BaM-style ``gpu_flash`` arm wins by dropping the
    host-CPU per-IO rent and servicing at the saturated queue rung.
  * ``diurnal``   — two tenant populations with staggered peaks and
    think gaps inside the pool band `[tau_be, tau_pool)`. The
    fleet-shared ``pool`` arm wins: discounted DRAM-class residency
    beats a flash re-read for exactly that interval range.

Acceptance (asserted by tests, reported here): each new tier shape
strictly beats the baseline on modeled $/token at equal-or-lower
per-token stall in its scenario, and the baseline platform's
`advise_tiers` four-arm comparison recommends a measured winner.

The JSON is deterministic (virtual clock, seeded draws, greedy decode):
CI runs `--smoke` twice and diffs the bytes.

  PYTHONPATH=src python benchmarks/serving_tiers.py --smoke
  PYTHONPATH=src python benchmarks/serving_tiers.py --out tiers.json
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool-blobs", type=int, default=64,
                    help="pool capacity in KV-blob units")
    ap.add_argument("--rent-factor", type=float, default=0.25,
                    help="pool rent as a fraction of local DRAM rent")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="pinned small packs for the CI determinism gate")
    ap.add_argument("--out", type=pathlib.Path, default=None)
    args = ap.parse_args()

    from repro.obs import write_bench_json
    from repro.serving.tiers import (ARM_ORDER, default_pool_decl,
                                     run_tiers_bench, scenario_packs)

    import dataclasses
    pool = dataclasses.replace(
        default_pool_decl(blobs=args.pool_blobs),
        rent_factor=args.rent_factor)
    packs = scenario_packs(smoke=args.smoke)
    out = run_tiers_bench(packs, pool=pool, max_slots=args.max_slots)
    write_bench_json(out, args.out)

    w = sys.stderr.write
    for scen in packs:
        cell = out[scen]
        base = cell["baseline"]["costs"]
        w(f"\n== {scen}  tau_be={cell['baseline']['tau_be']:.3f} s"
          f"  tau_pool={cell['pool'].get('tau_pool', float('nan')):.3f} s\n")
        w(f"   {'arm':10s} {'$/token':>14s} {'stall/token':>14s} "
          f"{'win':>5s}\n")
        for arm in ARM_ORDER:
            k = cell[arm]["costs"]
            win = "-" if arm == "baseline" else \
                ("yes" if cell["wins"][arm] else "no")
            w(f"   {arm:10s} {k['per_token']:14.8g} "
              f"{k['per_token_stall']:14.8g} {win:>5s}\n")
        w(f"   advisor recommends: {cell['advice']['recommended_arm']}"
          f"  (agrees with measurement: {cell['advice_agreement']})\n")
    w(f"\ngpu_flash wins somewhere: {out['gpu_flash_wins_somewhere']}\n"
      f"pool wins somewhere:      {out['pool_wins_somewhere']}\n")


if __name__ == "__main__":
    main()
