"""Paper-table/figure reproductions (one function per artifact).

Each function returns (rows, notes) where rows is a list of dicts; run.py
renders them. Acceptance anchors from the paper text are asserted here so
`python -m benchmarks.run` doubles as the reproduction check.
"""
from __future__ import annotations

import math
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (CPU_DDR, CPU_PLATFORM, GPU_GDDR, GPU_PLATFORM,
                        LatencyTargets, LogNormalWorkload, SLC, PSLC, TLC,
                        SsdConfig, analyze_platform, break_even,
                        break_even_components, iops_ssd_peak, normal_ssd,
                        rho_max_for_targets, storage_next_ssd,
                        tail_read_latency, usable_iops)
from repro.core.platform import PlatformConfig
import dataclasses


# ---------------------------------------------------------------------------
# Fig. 3 — peak IOPS vs block size for SLC / pSLC / TLC
# ---------------------------------------------------------------------------

def fig3_iops():
    rows = []
    for nand in (SLC, PSLC, TLC):
        for sn in (True, False):
            ssd = storage_next_ssd(nand) if sn else normal_ssd(nand)
            for l in (512, 1024, 2048, 4096):
                iops = float(iops_ssd_peak(ssd, l, 9.0, 3.0))
                rows.append({"nand": nand.name,
                             "ssd": "storage-next" if sn else "normal",
                             "l_blk": l, "iops_M": iops / 1e6})
    # anchors: SLC storage-next ~57M @512B, ~11M @4KB (paper §III-C)
    slc512 = next(r for r in rows if r["nand"] == "SLC"
                  and r["ssd"] == "storage-next" and r["l_blk"] == 512)
    slc4k = next(r for r in rows if r["nand"] == "SLC"
                 and r["ssd"] == "storage-next" and r["l_blk"] == 4096)
    assert abs(slc512["iops_M"] - 57.4) < 1.5, slc512
    assert abs(slc4k["iops_M"] - 11.1) < 0.6, slc4k
    return rows, "anchors OK: SLC/SN 57.4M@512B, 11.1M@4KB"


# ---------------------------------------------------------------------------
# Table II — sensitivity of peak IOPS to N_CH / N_NAND / tau_CMD
# ---------------------------------------------------------------------------

def table2_sensitivity():
    settings = {
        "pessimistic": dict(n_ch=16, n_nand=3, tau_cmd=200e-9),
        "baseline": dict(n_ch=20, n_nand=4, tau_cmd=150e-9),
        "optimistic": dict(n_ch=24, n_nand=5, tau_cmd=100e-9),
    }
    expect = {"pessimistic": (39.4, 8.5), "baseline": (57.4, 11.1),
              "optimistic": (79.3, 13.8)}
    rows = []
    for name, kw in settings.items():
        ssd = storage_next_ssd(SLC, **kw)
        i512 = float(iops_ssd_peak(ssd, 512, 9.0, 3.0)) / 1e6
        i4k = float(iops_ssd_peak(ssd, 4096, 9.0, 3.0)) / 1e6
        rows.append({"setting": name, **kw, "iops512_M": i512,
                     "iops4k_M": i4k})
        e512, e4k = expect[name]
        assert abs(i512 - e512) / e512 < 0.05, (name, i512, e512)
        assert abs(i4k - e4k) / e4k < 0.06, (name, i4k, e4k)
    return rows, "all three Table II rows within 6% of paper values"


# ---------------------------------------------------------------------------
# Fig. 4 — break-even interval stacks
# ---------------------------------------------------------------------------

def fig4_breakeven():
    rows = []
    for host in (CPU_DDR, GPU_GDDR):
        for nand in (SLC, PSLC, TLC):
            for sn in (True, False):
                ssd = storage_next_ssd(nand) if sn else normal_ssd(nand)
                for l in (512, 1024, 2048, 4096):
                    comp = break_even_components(
                        host, l, ssd.cost,
                        float(iops_ssd_peak(ssd, l, 9.0, 3.0)))
                    rows.append({
                        "host": host.name, "nand": nand.name,
                        "ssd": "SN" if sn else "NR", "l_blk": l,
                        "t_host": float(comp["host"]),
                        "t_dram": float(comp["dram_bw"]),
                        "t_ssd": float(comp["ssd"]),
                        "tau_be": float(sum(comp.values()))})
    # anchors: ~34s CPU/SLC/SN@512B, ~10s @4KB, ~5s GPU/SLC/SN@512B (7x)
    cpu512 = next(r for r in rows if r["host"] == "CPU+DDR"
                  and r["nand"] == "SLC" and r["ssd"] == "SN"
                  and r["l_blk"] == 512)
    cpu4k = next(r for r in rows if r["host"] == "CPU+DDR"
                 and r["nand"] == "SLC" and r["ssd"] == "SN"
                 and r["l_blk"] == 4096)
    gpu512 = next(r for r in rows if r["host"] == "GPU+GDDR"
                  and r["nand"] == "SLC" and r["ssd"] == "SN"
                  and r["l_blk"] == 512)
    assert abs(cpu512["tau_be"] - 34) < 3, cpu512["tau_be"]
    assert abs(cpu4k["tau_be"] - 10) < 2, cpu4k["tau_be"]
    assert abs(gpu512["tau_be"] - 5) < 1, gpu512["tau_be"]
    assert 5.5 < cpu512["tau_be"] / gpu512["tau_be"] < 8.5
    return rows, ("anchors OK: 34s CPU / 5s GPU @512B (7x), "
                  "minutes->seconds reproduced")


# ---------------------------------------------------------------------------
# Fig. 5 + Table IV — constraint-aware break-even
# ---------------------------------------------------------------------------

def table4_rho_tiers():
    """Tail-latency tiers chosen to equalize rho_max across block sizes."""
    ssd = storage_next_ssd(SLC)
    tiers = {0.70: {512: 7e-6, 1024: 9e-6, 2048: 11e-6, 4096: 16e-6},
             0.80: {512: 9e-6, 1024: 11e-6, 2048: 15e-6, 4096: 23e-6},
             0.90: {512: 13e-6, 1024: 17e-6, 2048: 26e-6, 4096: 44e-6},
             0.99: {512: 85e-6, 1024: 135e-6, 2048: 230e-6, 4096: 418e-6}}
    rows = []
    for target_rho, taus in tiers.items():
        for l, tau in taus.items():
            peak = float(iops_ssd_peak(ssd, l, 9.0, 3.0))
            rho = float(rho_max_for_targets(
                LatencyTargets(tail=tau), ssd.n_ch, peak,
                ssd.nand.tau_sense))
            rows.append({"tier_rho": target_rho, "l_blk": l,
                         "tau_tail_us": tau * 1e6, "rho_max": rho})
            assert abs(rho - target_rho) < 0.13, (l, tau, rho, target_rho)
    return rows, "Table IV tau<->rho_max mapping holds (M/D/1 Kingman)"


def fig5_constraints():
    ssd = storage_next_ssd(SLC)
    rows = []
    # (a)(b): host budget sweep, no latency cap
    for host, budgets in ((CPU_DDR, (40e6, 60e6, 80e6, 100e6)),
                          (GPU_GDDR, (160e6, 240e6, 320e6, 400e6))):
        for b in budgets:
            for l in (512, 1024, 2048, 4096):
                peak = float(iops_ssd_peak(ssd, l, 9.0, 3.0))
                use = float(usable_iops(peak, 1.0, b, 4))
                tau = float(break_even(host, l, ssd.cost, use))
                rows.append({"panel": "host-sweep", "host": host.name,
                             "budget_M": b / 1e6, "l_blk": l,
                             "tau_be": tau})
    # anchors: CPU 512B 40M->100M: 83s->47s; 4KB stays ~10s
    a = next(r for r in rows if r["host"] == "CPU+DDR"
             and r["budget_M"] == 40 and r["l_blk"] == 512)
    b_ = next(r for r in rows if r["host"] == "CPU+DDR"
              and r["budget_M"] == 100 and r["l_blk"] == 512)
    c = next(r for r in rows if r["host"] == "CPU+DDR"
             and r["budget_M"] == 100 and r["l_blk"] == 4096)
    assert abs(a["tau_be"] - 83) < 6, a["tau_be"]
    assert abs(b_["tau_be"] - 47) < 5, b_["tau_be"]
    assert abs(c["tau_be"] - 10) < 2, c["tau_be"]
    # (c)(d): tail-tier sweep at fixed budgets
    tiers = {0.70: 7e-6, 0.80: 9e-6, 0.90: 13e-6, 0.99: 85e-6}
    gpu_taus = {}
    for host, budget in ((CPU_DDR, 100e6), (GPU_GDDR, 400e6)):
        for rho_t, tau_tail in tiers.items():
            peak = float(iops_ssd_peak(ssd, 512, 9.0, 3.0))
            rho = float(rho_max_for_targets(
                LatencyTargets(tail=tau_tail), ssd.n_ch, peak,
                ssd.nand.tau_sense))
            use = float(usable_iops(peak, rho, budget, 4))
            tau = float(break_even(host, 512, ssd.cost, use))
            rows.append({"panel": "tail-sweep", "host": host.name,
                         "tier_rho": rho_t, "l_blk": 512, "tau_be": tau})
            if host.name == "GPU+GDDR":
                gpu_taus[rho_t] = tau
    # anchor: GPU 512B, 7us -> 85us tail relaxation buys only ~1.5s
    delta = gpu_taus[0.70] - gpu_taus[0.99]
    assert 0.5 < delta < 2.5, delta
    return rows, (f"anchors OK: 83->47s CPU host sweep; tail relaxation "
                  f"worth only {delta:.1f}s on GPU (latency is secondary)")


# ---------------------------------------------------------------------------
# Fig. 6 — workload-aware provisioning
# ---------------------------------------------------------------------------

def fig6_provisioning():
    rows = []
    tiers = {512: 13e-6, 1024: 17e-6, 2048: 26e-6, 4096: 44e-6}
    for plat in (CPU_PLATFORM, GPU_PLATFORM):
        for sn in (True, False):
            ssd = storage_next_ssd(SLC) if sn else normal_ssd(SLC)
            p = dataclasses.replace(plat, ssd=ssd)
            for l in (512, 1024, 2048, 4096):
                wl = LogNormalWorkload.from_total_throughput(
                    throughput=200e9, sigma=1.0, n_blk=1e9, l_blk=l)
                rep = analyze_platform(
                    p, wl, l, LatencyTargets(tail=tiers[l]))
                rows.append({
                    "platform": plat.name, "ssd": "SN" if sn else "NR",
                    "l_blk": l,
                    "tau_be": rep.tau_break_even,
                    "T_B": rep.th.t_b, "T_S": rep.th.t_s,
                    "C_viable_GB": rep.c_dram_viable / 1e9,
                    "C_opt_GB": rep.c_dram_optimal / 1e9,
                    "bw_use_opt_GBs": rep.dram_bw_use_optimal / 1e9,
                    "verdict": rep.verdict})
    # qualitative anchors from §V-B
    gpu_sn_512 = next(r for r in rows if r["platform"] == "GPU+GDDR"
                      and r["ssd"] == "SN" and r["l_blk"] == 512)
    cpu_sn_512 = next(r for r in rows if r["platform"] == "CPU+DDR"
                      and r["ssd"] == "SN" and r["l_blk"] == 512)
    assert gpu_sn_512["T_B"] < 5 and gpu_sn_512["T_S"] < 5
    assert gpu_sn_512["C_viable_GB"] < cpu_sn_512["C_viable_GB"]
    assert gpu_sn_512["C_opt_GB"] < cpu_sn_512["C_opt_GB"]
    return rows, ("GPU+SN viable with far less DRAM than CPU+DDR; "
                  "T_v < 5s on GPU+SN (paper Fig. 6)")


# ---------------------------------------------------------------------------
# Fig. 7 — simulator vs analytic model
# ---------------------------------------------------------------------------

def fig7_sim_vs_model(quick: bool = True):
    from repro.ssdsim import SimConfig, simulate_peak_iops
    from repro.core.ssd_model import iops_ssd_peak as model_iops
    n_ops = 30_000 if quick else 120_000
    rows = []
    ssd = storage_next_ssd(SLC)
    # (a)+(b): rw-mix sweep
    for rf, expect_M in ((1.0, 82), (0.9, 68), (0.7, 52), (0.5, 34)):
        sim = simulate_peak_iops(SimConfig(ssd=ssd, l_blk=512,
                                           read_frac=rf), n_ops=n_ops)
        model = float(model_iops(ssd, 512,
                                 rf / max(1 - rf, 1e-9) if rf < 1
                                 else float("inf"), 3.0))
        rows.append({"panel": "rw-mix", "read_frac": rf,
                     "sim_iops_M": sim.iops / 1e6,
                     "model_iops_M": model / 1e6,
                     "paper_sim_M": expect_M})
        assert abs(sim.iops / 1e6 - expect_M) / expect_M < 0.25, \
            (rf, sim.iops / 1e6, expect_M)
    # (c): channel bandwidth sweep
    for bch, expect_M in ((3.6e9, 68), (4.8e9, 78), (5.6e9, 85)):
        ssd_b = storage_next_ssd(SLC, b_ch=bch)
        sim = simulate_peak_iops(SimConfig(ssd=ssd_b, l_blk=512,
                                           read_frac=0.9), n_ops=n_ops)
        rows.append({"panel": "channel-bw", "b_ch_GBs": bch / 1e9,
                     "sim_iops_M": sim.iops / 1e6,
                     "paper_sim_M": expect_M})
        assert abs(sim.iops / 1e6 - expect_M) / expect_M < 0.25
    # (d): BCH escalation sweep
    base = None
    for p_bch in (0.0, 0.01, 0.05):
        sim = simulate_peak_iops(SimConfig(ssd=ssd, l_blk=512,
                                           read_frac=0.9, p_bch=p_bch),
                                 n_ops=n_ops)
        base = base or sim.iops
        rows.append({"panel": "ecc", "p_bch": p_bch,
                     "sim_iops_M": sim.iops / 1e6,
                     "vs_errorfree": sim.iops / base})
    near = [r for r in rows if r["panel"] == "ecc" and r["p_bch"] == 0.01]
    # "reduce throughput modestly, remaining near the error-free plateau
    # for <=1% failure rate" — we observe ~7% at 1%
    assert near[0]["vs_errorfree"] > 0.90
    return rows, ("simulator reproduces Fig. 7 trends: 82/68/52/34M rw-mix,"
                  " channel-bw scaling, ECC plateau <=1%")


# ---------------------------------------------------------------------------
# Fig. 8 — KV store throughput
# ---------------------------------------------------------------------------

def fig8_kvstore():
    from repro.kvstore.model import (KvWorkload, achievable_throughput,
                                     cpu_sn_platform, gpu_nr_platform,
                                     gpu_sn_platform)
    rows = []
    for plat in (gpu_sn_platform(), cpu_sn_platform(), gpu_nr_platform()):
        for gf in (1.0, 0.9, 0.7, 0.5):
            for sigma in (1.2, 0.4):
                for dram in (64e9, 256e9, 1024e9):
                    r = achievable_throughput(
                        plat, KvWorkload(get_frac=gf, sigma=sigma), dram)
                    rows.append({"platform": plat.name, "get_frac": gf,
                                 "sigma": sigma, "dram_GB": dram / 1e9,
                                 "Mops": r["throughput"] / 1e6,
                                 "limiter": r["limiter"],
                                 "hit": r["hit_rate"]})
    # anchors: GPU+SN read-heavy sustains 100+ Mops/s; CPU host-limited
    # below it; strong locality beats weak at equal capacity
    g = [r for r in rows if r["platform"] == "GPU+SN"
         and r["get_frac"] == 0.9 and r["sigma"] == 1.2
         and r["dram_GB"] == 256]
    c = [r for r in rows if r["platform"] == "CPU+SN"
         and r["get_frac"] == 0.9 and r["sigma"] == 1.2
         and r["dram_GB"] == 256]
    assert g[0]["Mops"] > 100, g
    assert c[0]["Mops"] < g[0]["Mops"]
    assert c[0]["limiter"] == "host-iops"
    weak = next(r for r in rows if r["platform"] == "GPU+SN"
                and r["get_frac"] == 0.9 and r["sigma"] == 0.4
                and r["dram_GB"] == 256)
    assert weak["Mops"] < g[0]["Mops"]
    return rows, ("GPU+SN sustains 100+ Mops/s read-heavy (in-memory-class);"
                  " CPU+SN host-IOPS-limited; locality spread reproduced")


# ---------------------------------------------------------------------------
# Fig. 10 — two-stage ANN search
# ---------------------------------------------------------------------------

def fig10_ann(quick: bool = True):
    from repro.ann.corpus import make_corpus, make_queries
    from repro.ann.model import (AnnWorkload, cpu_sn, gpu_nr, gpu_sn,
                                 throughput_kqps)
    from repro.ann.progressive import exact_topk, recall_at_k, search
    rows = []
    # recall validation on the MRL-like corpus (paper: >98%)
    n = 20000 if quick else 100000
    full, red, _ = make_corpus(n, 1024, 128)      # 4KB full / 512B reduced
    qs = make_queries(full, 200)
    truth = exact_topk(qs, full, 10)
    pred, stats = search(qs, red, full, k=10, promote=64)
    rec = recall_at_k(pred, truth)
    rows.append({"panel": "recall", "corpus": n, "recall@10": rec,
                 "promoted_frac": stats.stage2_reads / stats.stage1_reads})
    assert rec > 0.98, rec
    # throughput model across geometries (Fig. 10 a-d)
    for d_full, pf in ((2048, 0.05), (4096, 0.10), (6144, 0.15),
                       (8192, 0.20)):
        for plat in (gpu_sn(), cpu_sn(), gpu_nr()):
            for dram in (64e9, 256e9, 512e9):
                r = throughput_kqps(plat, AnnWorkload(
                    d_full_bytes=d_full, promote_frac=pf), dram)
                rows.append({"panel": f"512B->{d_full}B",
                             "platform": plat.name, "dram_GB": dram / 1e9,
                             "kqps": r["kqps"], "limiter": r["limiter"]})
    # anchors: GPU+SN tops every geometry; 2-3x+ over normal SSD;
    # rising with DRAM in light-promotion panels
    a = [r for r in rows if r.get("panel") == "512B->4096B"
         and r["platform"] == "GPU+SN"]
    nr = [r for r in rows if r.get("panel") == "512B->4096B"
          and r["platform"] == "GPU+NR"]
    assert a[-1]["kqps"] > a[0]["kqps"]
    assert min(x["kqps"] / y["kqps"] for x, y in zip(a, nr)) > 2.0
    return rows, (f"recall@10={rec:.3f} (>98%); GPU+SN {a[-1]['kqps']:.0f} "
                  "KQPS at 512GB, >=2-3x over normal SSD (DiskANN-class+)")


# ---------------------------------------------------------------------------
# Beyond-paper: TCO + CXL tier ladder (paper §VIII future work, built)
# ---------------------------------------------------------------------------

def tco_ladder():
    from repro.core.tco import reference_tiers, tier_ladder, place, \
        tco_break_even
    ssd = storage_next_ssd(SLC)
    rows = []
    for l in (512, 4096):
        ladder = tier_ladder(l, reference_tiers(ssd, l_blk=l))
        for name, tau in ladder:
            rows.append({"l_blk": l, "tier": name,
                         "stay_below_s": tau})
    ladder512 = tier_ladder(512, reference_tiers(ssd))
    names = [n for n, _ in ladder512]
    taus = [t for _, t in ladder512]
    assert names == ["HBM", "DRAM", "CXL-DRAM", "FLASH-SN"]
    assert all(a < b for a, b in zip(taus[:-1], taus[1:]))
    # OpEx direction finding
    tiers = reference_tiers(ssd)
    capex = tco_break_even(512, tiers[1], tiers[3], power_cost=0.0)
    full = tco_break_even(512, tiers[1], tiers[3])
    return rows, (
        f"4-tier ladder @512B: HBM<{taus[0]:.3f}s<DRAM<{taus[1]:.1f}s<"
        f"CXL<{taus[2]:.1f}s<flash; TCO (energy) lengthens the DRAM-flash "
        f"threshold {capex:.0f}s->{full:.0f}s: fetch energy dominates "
        "refresh power at $0.10/kWh")


# ---------------------------------------------------------------------------
# Beyond-paper: async-prefetch serving stall (queueing-aware runtime)
# ---------------------------------------------------------------------------

def serving_async(quick: bool = True):
    """Sync vs async KV restore on the multi-turn session workload —
    modeled per-token stall must drop under async prefetch."""
    from repro.serving.bench import compare
    kw = dict(n_sessions=8, rounds=2, kv_bytes=1 << 20,
              decode_steps=16, step_time=2e-3, lead=8) if quick else \
        dict(n_sessions=32, rounds=4, kv_bytes=4 << 20,
             decode_steps=64, step_time=2e-3, lead=16)
    r = compare(**kw)
    rows = [{"mode": m,
             "stall_per_token_us": d["per_token_stall"] * 1e6,
             "total_stall_ms": d["total_stall"] * 1e3,
             "makespan_ms": d["makespan"] * 1e3,
             "prefetch_hits": int(d["prefetch_hits"]),
             "miss_under_miss": int(d["miss_under_miss"])}
            for m, d in r.items()]
    gain = r["sync"]["per_token_stall"] / max(
        r["async"]["per_token_stall"], 1e-12)
    assert r["async"]["per_token_stall"] < r["sync"]["per_token_stall"]
    return rows, (f"async prefetch cuts modeled per-token stall {gain:.1f}x"
                  " (queueing-aware flash service from ssdsim)")
