"""Fleet-scale sharded serving benchmark (multi-host tiering fabric).

Sweeps host count x session-popularity skew on the sharded
`ShardedTieredStore` fabric: sessions pause on one host and resume on
another, so KV restores compose the NIC transfer tier with the owner
host's calibrated flash queue. For every cell the sync restore path is
compared against async cross-host prefetch on the identical seeded
schedule, and the JSON trajectory (one record per cell, both modes +
stall speedup) is printed/written.

Elasticity (`--churn`): every cell additionally runs the identical
async schedule with a host join at mid-schedule (N -> N+1) — the fabric
streams the remapped ~1/(N+1) of resident keys as background rebalance
traffic on the shared clock — and reports the measured rebalance
fraction plus the rebalance tax (added per-token stall vs the no-churn
baseline). `--leave-turn` adds a host departure after the join.

`--lead p99` sizes prefetch leads per turn from the owner flash tier's
calibrated open-loop p99 (+ NIC leg) instead of a fixed step count;
`--locality` reroutes each resume to a host already holding the
session's KV replica.

Everything runs on one shared VirtualClock with fixed seeds, so the
emitted JSON is byte-identical across runs — CI executes `--smoke`
twice and diffs the outputs as a determinism gate (the suite also does
this in-process, churn schedule included).

Declarative mode (`--spec fleet.json`, a `repro.platform.HierarchySpec`
serialized via `spec.to_json()`): the fleet — per-host tier geometry,
capacity-weighted ring, policy, NIC/topology — compiles from the spec
instead of the `--hosts` keyword dialect. A homogeneous pinned-flash
spec reproduces the keyword path byte-for-byte; a heterogeneous spec
(one host with 2x DRAM) with `--kv-tier dram` shows the weighted ring's
stall win over `weighting="uniform"`.

  PYTHONPATH=src python benchmarks/serving_fleet.py --smoke
  PYTHONPATH=src python benchmarks/serving_fleet.py --smoke --churn
  PYTHONPATH=src python benchmarks/serving_fleet.py --hosts 2,4,8 \
      --skew 0.0,1.2 --lead p99 --locality --out fleet.json
  PYTHONPATH=src python benchmarks/serving_fleet.py --spec fleet_spec.json \
      --kv-tier dram
"""
import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.policy import Tier  # noqa: E402
from repro.obs import write_bench_json  # noqa: E402
from repro.serving.bench import compare_churn, compare_fleet  # noqa: E402


def run_sweep(hosts, skews, *, n_sessions, rounds, kv_bytes, decode_steps,
              step_time, lead, seed, locality=False, churn=None,
              rebalance_rate=None, spec=None, kv_tier=Tier.FLASH):
    trajectory = []
    for h in hosts:
        for sk in skews:
            kw = dict(
                n_sessions=n_sessions, rounds=rounds,
                kv_bytes=kv_bytes, decode_steps=decode_steps,
                step_time=step_time, lead=lead, skew=sk, seed=seed,
                locality=locality, rebalance_rate=rebalance_rate,
                kv_tier=kv_tier)
            if spec is not None:
                kw["spec"] = spec
            else:
                kw["n_hosts"] = h
            cell = compare_fleet(**kw)
            if churn:
                # the cell's async record IS the no-churn baseline
                # (byte-identical runs) — don't simulate it a third time
                cell["churn"] = compare_churn(churn,
                                              baseline=cell["async"],
                                              **kw)
            trajectory.append({"hosts": h, "skew": sk, **cell})
    return trajectory


# defaults per mode; an explicitly-passed flag always overrides either.
# churn smoke uses more, smaller sessions so the measured rebalance
# fraction concentrates near the 1/(N+1) consistent-hash ideal instead
# of the high variance a handful of keys would show.
_FULL = dict(hosts="2,4,8", skew="0.0,1.2", sessions=16, rounds=2,
             kv_mib=1.0, decode_steps=16, step_time_ms=2.0, lead="8")
_SMOKE = dict(hosts="4", skew="0.0,1.2", sessions=8, rounds=2,
              kv_mib=0.5, decode_steps=8, step_time_ms=2.0, lead="6")
_SMOKE_CHURN = dict(_SMOKE, sessions=32, kv_mib=0.25)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", default=None,
                    help=f"comma-separated host counts "
                         f"(default {_FULL['hosts']}; smoke "
                         f"{_SMOKE['hosts']})")
    ap.add_argument("--skew", default=None,
                    help="comma-separated Zipf skews")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--kv-mib", type=float, default=None)
    ap.add_argument("--decode-steps", type=int, default=None)
    ap.add_argument("--step-time-ms", type=float, default=None)
    ap.add_argument("--lead", default=None,
                    help="prefetch lead in decode steps, or 'p99' to "
                         "size it from the calibrated tail per turn")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--locality", action="store_true",
                    help="route each resume to a host already holding "
                         "the session's KV replica")
    ap.add_argument("--churn", action="store_true",
                    help="per cell, also run the identical async "
                         "schedule with a host join at mid-schedule and "
                         "report the rebalance tax")
    ap.add_argument("--join-turn", type=int, default=None,
                    help="churn: turn before which the host joins "
                         "(default: mid-schedule)")
    ap.add_argument("--leave-turn", type=int, default=None,
                    help="churn: turn before which the newest host "
                         "leaves again")
    ap.add_argument("--pace-gbs", type=float, default=None,
                    help="churn: cap rebalance streams at this many "
                         "GB/s per source host (token bucket); default "
                         "unpaced")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast defaults (4 hosts) for CI "
                         "determinism; explicit flags still apply")
    ap.add_argument("--spec", type=pathlib.Path, default=None,
                    help="declarative mode: compile the fleet from this "
                         "HierarchySpec JSON (spec.to_json()); --hosts "
                         "is ignored, the spec defines the fleet")
    ap.add_argument("--kv-tier", choices=("flash", "dram"),
                    default="flash",
                    help="pause/landing tier ask: flash measures the "
                         "restore path (default); dram exercises "
                         "capacity placement on heterogeneous specs")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()

    # a join/leave turn implies churn mode — silently ignoring the flag
    # would report a no-churn sweep as an elasticity measurement
    args.churn = args.churn or args.join_turn is not None \
        or args.leave_turn is not None
    base = (_SMOKE_CHURN if args.churn else _SMOKE) if args.smoke \
        else _FULL

    def arg(name):
        v = getattr(args, name)
        return base[name] if v is None else v

    spec = None
    if args.spec is not None:
        from repro.platform import HierarchySpec
        spec = HierarchySpec.from_json(args.spec.read_text())
        hosts = [spec.n_hosts]
    else:
        hosts = [int(x) for x in str(arg("hosts")).split(",")]
    skews = [float(x) for x in str(arg("skew")).split(",")]
    lead = str(arg("lead"))
    lead = lead if lead == "p99" else int(lead)
    churn = None
    if args.churn:
        n_turns = int(arg("rounds")) * int(arg("sessions"))
        join = n_turns // 2 if args.join_turn is None else args.join_turn
        # an event past the schedule would silently never fire and a
        # no-churn run would masquerade as an elasticity measurement
        if not 0 <= join < n_turns:
            ap.error(f"--join-turn must be in [0, {n_turns})")
        churn = {"join_turn": join}
        if args.leave_turn is not None:
            if not 0 <= args.leave_turn < n_turns:
                ap.error(f"--leave-turn must be in [0, {n_turns})")
            churn["leave_turn"] = args.leave_turn
    params = dict(n_sessions=arg("sessions"), rounds=arg("rounds"),
                  kv_bytes=int(arg("kv_mib") * 2**20),
                  decode_steps=arg("decode_steps"),
                  step_time=arg("step_time_ms") * 1e-3,
                  lead=lead, seed=args.seed, locality=args.locality,
                  churn=churn,
                  rebalance_rate=(args.pace_gbs * 1e9
                                  if args.pace_gbs else None))

    trajectory = run_sweep(hosts, skews, spec=spec,
                           kv_tier=Tier[args.kv_tier.upper()], **params)
    report = {"params": {**params, "hosts": hosts, "skews": skews,
                         "kv_tier": args.kv_tier,
                         "spec": None if spec is None else
                         json.loads(spec.to_json())},
              "trajectory": trajectory}
    write_bench_json(report, out=args.out)

    print(f"\n{'hosts':>5s} {'skew':>5s} {'sync us/tok':>12s} "
          f"{'async us/tok':>13s} {'speedup':>8s} {'remote':>7s}",
          file=sys.stderr)
    for rec in trajectory:
        print(f"{rec['hosts']:5d} {rec['skew']:5.1f} "
              f"{rec['sync']['per_token_stall']*1e6:12.1f} "
              f"{rec['async']['per_token_stall']*1e6:13.1f} "
              f"{rec['stall_speedup']:8.1f} "
              f"{int(rec['async']['remote_fetches']):7d}",
              file=sys.stderr)
        if "churn" in rec:
            ch = rec["churn"]
            print(f"      churn: moved "
                  f"{ch['rebalance_bytes']/2**20:.2f}MiB "
                  f"({ch['rebalance_fraction']*100:.1f}% of resident, "
                  f"ideal {100.0/(rec['hosts']+1):.1f}%), stall x"
                  f"{ch['stall_ratio']:.2f} "
                  f"(+{ch['added_stall_per_token']*1e6:.2f}us/tok)",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
