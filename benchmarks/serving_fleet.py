"""Fleet-scale sharded serving benchmark (multi-host tiering fabric).

Sweeps host count x session-popularity skew on the sharded
`ShardedTieredStore` fabric: sessions pause on one host and resume on
another, so KV restores compose the NIC transfer tier with the owner
host's calibrated flash queue. For every cell the sync restore path is
compared against async cross-host prefetch on the identical seeded
schedule, and the JSON trajectory (one record per cell, both modes +
stall speedup) is printed/written.

Everything runs on one shared VirtualClock with fixed seeds, so the
emitted JSON is byte-identical across runs — CI executes `--smoke`
twice and diffs the outputs as a determinism gate.

  PYTHONPATH=src python benchmarks/serving_fleet.py --smoke
  PYTHONPATH=src python benchmarks/serving_fleet.py --hosts 2,4,8 \
      --skew 0.0,1.2 --out fleet.json
"""
import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.serving.bench import compare_fleet  # noqa: E402


def run_sweep(hosts, skews, *, n_sessions, rounds, kv_bytes, decode_steps,
              step_time, lead, seed):
    trajectory = []
    for h in hosts:
        for sk in skews:
            cell = compare_fleet(
                n_hosts=h, n_sessions=n_sessions, rounds=rounds,
                kv_bytes=kv_bytes, decode_steps=decode_steps,
                step_time=step_time, lead=lead, skew=sk, seed=seed)
            trajectory.append({"hosts": h, "skew": sk, **cell})
    return trajectory


# defaults per mode; an explicitly-passed flag always overrides either
_FULL = dict(hosts="2,4,8", skew="0.0,1.2", sessions=16, rounds=2,
             kv_mib=1.0, decode_steps=16, step_time_ms=2.0, lead=8)
_SMOKE = dict(hosts="4", skew="0.0,1.2", sessions=8, rounds=2,
              kv_mib=0.5, decode_steps=8, step_time_ms=2.0, lead=6)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", default=None,
                    help=f"comma-separated host counts "
                         f"(default {_FULL['hosts']}; smoke "
                         f"{_SMOKE['hosts']})")
    ap.add_argument("--skew", default=None,
                    help="comma-separated Zipf skews")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--kv-mib", type=float, default=None)
    ap.add_argument("--decode-steps", type=int, default=None)
    ap.add_argument("--step-time-ms", type=float, default=None)
    ap.add_argument("--lead", type=int, default=None,
                    help="prefetch lead in decode steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast defaults (4 hosts) for CI "
                         "determinism; explicit flags still apply")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()

    base = _SMOKE if args.smoke else _FULL

    def arg(name):
        v = getattr(args, name)
        return base[name] if v is None else v

    hosts = [int(x) for x in str(arg("hosts")).split(",")]
    skews = [float(x) for x in str(arg("skew")).split(",")]
    params = dict(n_sessions=arg("sessions"), rounds=arg("rounds"),
                  kv_bytes=int(arg("kv_mib") * 2**20),
                  decode_steps=arg("decode_steps"),
                  step_time=arg("step_time_ms") * 1e-3,
                  lead=arg("lead"), seed=args.seed)

    trajectory = run_sweep(hosts, skews, **params)
    report = {"params": {**params, "hosts": hosts, "skews": skews},
              "trajectory": trajectory}
    js = json.dumps(report, sort_keys=True, indent=2)
    if args.out:
        args.out.write_text(js + "\n")
    print(js)

    print(f"\n{'hosts':>5s} {'skew':>5s} {'sync us/tok':>12s} "
          f"{'async us/tok':>13s} {'speedup':>8s} {'remote':>7s}",
          file=sys.stderr)
    for rec in trajectory:
        print(f"{rec['hosts']:5d} {rec['skew']:5.1f} "
              f"{rec['sync']['per_token_stall']*1e6:12.1f} "
              f"{rec['async']['per_token_stall']*1e6:13.1f} "
              f"{rec['stall_speedup']:8.1f} "
              f"{int(rec['async']['remote_fetches']):7d}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
