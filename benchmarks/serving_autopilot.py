"""Autopilot serving benchmark: break-even admission vs static placement.

Replays four scenario traces (Zipf, scan-flood, diurnal hotspot shift,
bursty multi-tenant) against a capacity-bound TieredStore under three
policies — the EconomicGate (tracked reuse vs calibrated break-even),
always-DRAM (LRU-ish capacity pressure, the seed behavior), and
always-flash — and reports modeled $/token (DRAM rent + DRAM wire +
flash IO + host CPU + stalled-accelerator time, in the paper's
normalized units) plus per-token stall. The acceptance criterion per
scenario: the gate's $/token must not exceed the best static baseline's
at equal-or-lower per-token stall.

The economic run also emits the live ProvisionAdvisor output (measured
hot set, DRAM:flash split, host count, limiting resource) — the same
telemetry the gate steers by, turned into provisioning guidance.

`--autoscale` runs the closed provisioning loop instead: a one-host
platform on the diurnal trace where `Platform.autoscale` lets the
`ProvisionAdvisor` drive `add_host`/`remove_host` (under the rebalance
pacer) — the fleet grows a host for the peak and hands it back
off-peak — priced against a static fleet provisioned for the peak.

`--failover` runs the kill-a-host-at-diurnal-peak scenario instead:
replication arms r in {1,2,3} replay the same trace on a four-host
fleet, the busiest host dies unplanned at the peak, the repair loop
re-replicates under the rebalance pacer, and checkpointed sessions
fail over to surviving hosts. Reports recovery time, lost committed
keys/sessions and $/token per arm, plus the advisor's recommended
replication factor under the bench's MTTF (acceptance: zero committed
loss with r>=2, every session resumes, and the recommendation beats
both r=1 and r=3 on measured $/token).

Everything runs on a VirtualClock with seeded traces, so the JSON is
byte-identical across runs; CI executes `--smoke` twice and diffs.

`--trace` attaches the causal tracer to the scenario suite and writes
the Perfetto/Chrome trace_event export (open at ui.perfetto.dev) —
byte-identical across runs, which CI also diffs.

  PYTHONPATH=src python benchmarks/serving_autopilot.py --smoke
  PYTHONPATH=src python benchmarks/serving_autopilot.py --smoke --trace
  PYTHONPATH=src python benchmarks/serving_autopilot.py --autoscale
  PYTHONPATH=src python benchmarks/serving_autopilot.py --failover
  PYTHONPATH=src python benchmarks/serving_autopilot.py \
      --steps 240 --scenarios zipf,scan_flood --out autopilot.json
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.autopilot.bench import run_suite  # noqa: E402
from repro.autopilot.traces import SCENARIOS  # noqa: E402
from repro.obs import write_bench_json  # noqa: E402


def run_autoscale(args):
    from repro.platform import run_autoscale_bench
    report = run_autoscale_bench(
        scenario=args.autoscale_scenario,
        n_steps=120 if args.smoke else args.steps,
        step_time=args.step_time_ms * 1e-3,
        l_blk=int(args.l_blk_kib * 1024),
        alpha_accel=args.alpha_accel, seed=args.seed)
    write_bench_json(report, out=args.out)

    a, s = report["autoscaled"], report["static"]
    print(f"\n{'arm':>10s} {'hosts':>11s} {'$/tok':>10s} "
          f"{'stall us/tok':>13s} {'host-sec':>9s}", file=sys.stderr)
    for name, r in (("autoscaled", a), ("static", s)):
        span = (f"{int(r['hosts_start'])}->{int(r['hosts_peak'])}->"
                f"{int(r['hosts_final'])}")
        print(f"{name:>10s} {span:>11s} {r['cost_per_token']:10.6f} "
              f"{r['per_token_stall']*1e6:13.1f} "
              f"{r['host_seconds']:9.1f}", file=sys.stderr)
    for d in a.get("decisions", []):
        print(f"  t={int(d['step']):3d} {d['action']:>6s} -> "
              f"{int(d['n_hosts'])} host(s) (advisor: "
              f"{int(d['recommended'])}): {d['reason']}", file=sys.stderr)
    print(f"\nautoscale wins on $/token: {report['autoscale_wins']} "
          f"(x{report['cost_ratio_vs_static']:.3f} vs static); final "
          f"fleet within one host of advice: "
          f"{report['final_within_one_of_advice']}", file=sys.stderr)


def run_failover(args):
    from repro.platform import run_failover_bench
    report = run_failover_bench(
        scenario=args.autoscale_scenario,
        n_steps=100 if args.smoke else args.steps,
        n_sessions=8 if args.smoke else 12,
        step_time=args.step_time_ms * 1e-3,
        l_blk=int(args.l_blk_kib * 1024),
        alpha_accel=args.alpha_accel, seed=args.seed)
    write_bench_json(report, out=args.out)

    print(f"\n{'arm':>4s} {'$/tok':>10s} {'stall us/tok':>13s} "
          f"{'lost keys':>9s} {'lost sess':>9s} {'resumed':>8s} "
          f"{'recovery s':>10s}", file=sys.stderr)
    rec = int(report["recommended_replicas"])
    for r, arm in sorted(report["arms"].items()):
        tag = "*" if int(r) == rec else " "
        print(f" r={r}{tag} {arm['cost_per_token']:10.6f} "
              f"{arm['per_token_stall']*1e6:13.1f} "
              f"{int(arm['committed_keys_lost']):9d} "
              f"{int(arm['sessions_lost']):9d} "
              f"{int(arm['sessions_resumed']):8d} "
              f"{arm['recovery_seconds']:10.4f}", file=sys.stderr)
    print(f"\nadvisor recommends r={rec} "
          f"(mttf={report['params']['mttf']:.0f}s); beats both "
          f"alternatives on $/token: {report['recommended_wins']}; "
          f"zero committed loss (r>=2): "
          f"{report['zero_committed_loss_replicated']}; all sessions "
          f"resume (r>=2): {report['all_sessions_resume_replicated']}",
          file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default=",".join(SCENARIOS),
                    help="comma-separated scenario names")
    ap.add_argument("--steps", type=int, default=240,
                    help="trace length in decode steps")
    ap.add_argument("--step-time-ms", type=float, default=250.0,
                    help="modeled compute per step (ms)")
    ap.add_argument("--l-blk-kib", type=float, default=128.0,
                    help="object size (KiB)")
    ap.add_argument("--dram-frac", type=float, default=0.35,
                    help="DRAM capacity as a fraction of the recurring "
                         "working set")
    ap.add_argument("--alpha-accel", type=float, default=4.0,
                    help="normalized rent of the serving resource a "
                         "demand miss idles ($/s, NAND die == 1 — the "
                         "same units as alpha_core); enters both the "
                         "cost model and the gate's break-even")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace (120 steps) for the CI "
                         "determinism gate")
    ap.add_argument("--autoscale", action="store_true",
                    help="run the closed provisioning loop on the "
                         "diurnal trace (advisor-driven add/remove "
                         "host) vs a peak-provisioned static fleet")
    ap.add_argument("--failover", action="store_true",
                    help="run the kill-a-host-at-diurnal-peak scenario "
                         "(replication arms r=1..3, unplanned failure "
                         "+ paced repair + session failover) and the "
                         "advisor's replication recommendation")
    ap.add_argument("--autoscale-scenario", default="diurnal",
                    help="trace scenario for --autoscale/--failover")
    ap.add_argument("--trace", action="store_true",
                    help="attach the causal tracer to the scenario "
                         "suite and export a Perfetto/Chrome "
                         "trace_event JSON (deterministic bytes)")
    ap.add_argument("--trace-out", type=pathlib.Path, default=None,
                    help="trace export path (default "
                         "autopilot_trace.json)")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()

    if args.autoscale:
        return run_autoscale(args)
    if args.failover:
        return run_failover(args)

    scenarios = [s for s in str(args.scenarios).split(",") if s]
    n_steps = 120 if args.smoke else args.steps
    obs = None
    if args.trace:
        from repro.obs import Observability
        obs = Observability(trace=True)
    report = run_suite(
        scenarios, n_steps=n_steps,
        step_time=args.step_time_ms * 1e-3,
        l_blk=int(args.l_blk_kib * 1024), dram_frac=args.dram_frac,
        alpha_accel=args.alpha_accel, seed=args.seed, obs=obs)
    report["params"] = {
        "scenarios": scenarios, "n_steps": n_steps,
        "step_time_ms": args.step_time_ms, "l_blk_kib": args.l_blk_kib,
        "dram_frac": args.dram_frac, "alpha_accel": args.alpha_accel,
        "seed": args.seed,
    }
    if obs is not None:
        report["stall_ledger"] = obs.ledger.as_dict()
    write_bench_json(report, out=args.out)

    if obs is not None:
        trace_out = args.trace_out or pathlib.Path("autopilot_trace.json")
        trace_out.write_text(obs.tracer.to_chrome_json() + "\n")
        print(f"\nperfetto trace: {trace_out} "
              f"({len(obs.tracer)} events, "
              f"{obs.tracer.dropped} dropped) — open at ui.perfetto.dev",
              file=sys.stderr)
        flame = obs.tracer.flamegraph().splitlines()
        for line in flame[:12]:
            print(f"  {line}", file=sys.stderr)
        if len(flame) > 12:
            print(f"  ... ({len(flame) - 12} more stacks)",
                  file=sys.stderr)

    print(f"\n{'scenario':>12s} {'mode':>9s} {'$/tok':>10s} "
          f"{'stall us/tok':>13s} {'rent':>7s} {'flashIO':>8s} "
          f"{'stall$':>7s}", file=sys.stderr)
    for cell in report["scenarios"]:
        for mode in ("economic", "dram", "flash"):
            r = cell["runs"][mode]
            tag = "*" if mode == cell["best_static"] else " "
            print(f"{cell['scenario']:>12s} {mode:>8s}{tag} "
                  f"{r['cost_per_token']:10.6f} "
                  f"{r['per_token_stall']*1e6:13.1f} "
                  f"{r['cost_dram_rent']:7.3f} {r['cost_flash_io']:8.3f} "
                  f"{r['cost_stall']:7.3f}", file=sys.stderr)
        print(f"{'':>12s} gate_wins={cell['gate_wins']} "
              f"(cost x{cell['cost_ratio_vs_best_static']:.2f} vs best "
              f"static)", file=sys.stderr)
    print(f"\ngate wins {report['wins']}/{report['cells']} scenarios "
          f"(acceptance: >= 3/4)", file=sys.stderr)


if __name__ == "__main__":
    main()
