"""Benchmark harness: one artifact per paper table/figure + the dry-run
roofline grid. `python -m benchmarks.run [--full] [--skip-roofline]`.

Each paper artifact asserts its acceptance anchors (numbers quoted in the
paper text), so a green run IS the reproduction check.
"""
from __future__ import annotations

import argparse
import sys
import time
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _render(name, rows, note, show=6):
    print(f"\n=== {name} " + "=" * max(1, 66 - len(name)))
    if rows:
        keys = list(rows[0].keys())
        print(" | ".join(f"{k}" for k in keys))
        for r in rows[:show]:
            print(" | ".join(
                f"{v:.4g}" if isinstance(v, float) else str(v)
                for v in r.values()))
        if len(rows) > show:
            print(f"... ({len(rows)} rows total)")
    print(f"--> {note}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="long simulator runs (more ops)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import paper_figs as pf

    t0 = time.time()
    artifacts = [
        ("Fig. 3  SSD peak IOPS vs block size", pf.fig3_iops, {}),
        ("Table II  IOPS sensitivity (N_CH/N_NAND/tau_CMD)",
         pf.table2_sensitivity, {}),
        ("Fig. 4  break-even interval stacks", pf.fig4_breakeven, {}),
        ("Table IV  tail-latency tiers <-> rho_max", pf.table4_rho_tiers,
         {}),
        ("Fig. 5  constraint-aware break-even", pf.fig5_constraints, {}),
        ("Fig. 6  workload-aware provisioning", pf.fig6_provisioning, {}),
        ("Fig. 7  MQSim-Next vs analytic model", pf.fig7_sim_vs_model,
         {"quick": quick}),
        ("Fig. 8  SSD-resident KV store throughput", pf.fig8_kvstore, {}),
        ("Fig. 10  two-stage progressive ANN", pf.fig10_ann,
         {"quick": quick}),
        ("Beyond-paper: TCO + CXL 4-tier ladder (paper §VIII)",
         pf.tco_ladder, {}),
        ("Beyond-paper: async-prefetch serving stall (runtime)",
         pf.serving_async, {"quick": quick}),
    ]
    failures = []
    for name, fn, kw in artifacts:
        t = time.time()
        try:
            rows, note = fn(**kw)
            _render(name, rows, note)
            print(f"    [{time.time()-t:.1f}s]")
        except AssertionError as e:
            failures.append((name, e))
            print(f"\n=== {name}\n--> ANCHOR FAILED: {e}")
        except Exception as e:
            failures.append((name, e))
            print(f"\n=== {name}\n--> ERROR: {type(e).__name__}: {e}")

    if not args.skip_roofline:
        print("\n=== Dry-run roofline grid " + "=" * 42)
        try:
            from benchmarks import roofline_report
            res = roofline_report.load("single")
            if res:
                print(roofline_report.single_pod_table(res))
                multi = roofline_report.load("multi")
                if multi:
                    print("\n-- multi-pod (2x16x16) --")
                    print(roofline_report.multi_pod_table(multi))
                vt = roofline_report.variant_table()
                if vt:
                    print("\n-- hillclimb variants (vs baseline) --")
                    print(vt)
            else:
                print("(no results/dryrun/*.json yet — run "
                      "`python -m repro.launch.dryrun --all`)")
        except Exception as e:
            print(f"roofline report unavailable: {e}")

    print(f"\n{'='*72}\n{len(artifacts)-len(failures)}/{len(artifacts)} "
          f"paper artifacts reproduced in {time.time()-t0:.0f}s")
    for name, e in failures:
        print(f"  FAILED: {name}: {e}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
