"""Serving-scale benchmark: the vectorized control plane at 1M keys,
plus continuous-vs-lockstep scheduling on the autopilot traces.

Two parts, one JSON report:

  * `scale`: replays a seeded 1M-key / 100k-session trace through the
    batched control plane (`repro.serving.scale`) — consistent-hash
    routing via `owner_batch`, array-ghost reuse tracking feeding one
    sketch update per step, vectorized break-even admission and array
    LRU, and queued flash misses priced off the `SsdQueueModel` depth
    ladder. The JSON carries only the *modeled* results and op
    counters (deterministic, byte-stable — CI runs `--smoke` twice and
    diffs); the measured wall-clock cost per control-plane section
    prints to stderr, separately from modeled stall, because it is a
    property of the machine, not of the model.

  * `compare`: races `ContinuousScheduler` (per-step admission against
    the splice-jit cache, pause-on-idle into the tiered store,
    prefetch-led resume) against the lock-step gang reference on
    multi-turn jobs derived from the autopilot trace scenarios. Both
    arms must emit byte-identical tokens (greedy decode); the race is
    modeled tokens/sec and per-token stall (KV restore stalls + idle
    slot-time in the same currency). Acceptance: continuous >= lockstep
    tokens/sec at equal-or-lower stall on every scenario.

  PYTHONPATH=src python benchmarks/serving_scale.py --smoke
  PYTHONPATH=src python benchmarks/serving_scale.py \
      --keys 1000000 --sessions 100000 --steps 120
  PYTHONPATH=src python benchmarks/serving_scale.py \
      --scenarios zipf,diurnal --out scale.json
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def run_compare(scenarios, *, smoke: bool, seed: int):
    import jax
    from repro.configs import get_config
    from repro.core.policy import TieringPolicy
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    from repro.runtime.clock import VirtualClock
    from repro.runtime.tiers import TieredStore
    from repro.serving import (DecodeEngine, compare_scheduling,
                               jobs_from_trace)
    from repro.serving.engine import splice_trace_counts

    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)

    def engine_factory():
        clock = VirtualClock()
        # pinned-flash policy: every pause lands on flash, so resumes
        # pay (and prefetch hides) a real queued restore
        store = TieredStore(
            TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0),
            clock=clock)
        return DecodeEngine(cfg, params, rules, max_slots=4, max_len=64,
                            store=store, step_time=2e-3)

    n_jobs = 6 if smoke else 10
    horizon = 48 if smoke else 96
    out = {}
    for scen in scenarios:
        cell = compare_scheduling(
            engine_factory,
            lambda: jobs_from_trace(scen, n_jobs=n_jobs, n_turns=2,
                                    tokens_per_turn=5, vocab=cfg.vocab,
                                    horizon=horizon, seed=seed),
            pause_idle_steps=4)
        out[scen] = cell
    out["splice_traces"] = {k: float(v)
                            for k, v in splice_trace_counts().items()}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1_000_000,
                    help="control-plane keyspace size")
    ap.add_argument("--sessions", type=int, default=100_000,
                    help="multi-turn sessions inside the keyspace")
    ap.add_argument("--steps", type=int, default=120,
                    help="fleet steps to replay")
    ap.add_argument("--accesses", type=int, default=50_000,
                    help="object accesses per step (sessions add their "
                         "turn arrivals on top)")
    ap.add_argument("--hosts", type=int, default=8)
    ap.add_argument("--tau-be", type=float, default=5.0,
                    help="break-even interval for the vectorized gate")
    ap.add_argument("--scenarios", default="zipf,diurnal",
                    help="autopilot trace scenarios for the "
                         "continuous-vs-lockstep race")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the CI determinism gate")
    ap.add_argument("--skip-compare", action="store_true",
                    help="scale replay only (no model decode)")
    ap.add_argument("--no-metrics", action="store_true",
                    help="run the replay without the metrics plane "
                         "(CI compares wall time against the default "
                         "metrics-on run; modeled JSON is identical)")
    ap.add_argument("--out", type=pathlib.Path, default=None)
    args = ap.parse_args()

    from repro.obs import Observability, write_bench_json
    from repro.serving.scale import scale_replay

    if args.smoke:
        scale_kw = dict(n_keys=200_000, n_sessions=20_000, n_steps=30,
                        accesses_per_step=10_000, n_hosts=args.hosts,
                        tau_be=args.tau_be, seed=args.seed)
    else:
        scale_kw = dict(n_keys=args.keys, n_sessions=args.sessions,
                        n_steps=args.steps,
                        accesses_per_step=args.accesses,
                        n_hosts=args.hosts, tau_be=args.tau_be,
                        seed=args.seed)
    obs = None if args.no_metrics else Observability()
    record, timings = scale_replay(**scale_kw, obs=obs)

    report = {"scale": record, "params": {
        **{k: float(v) for k, v in scale_kw.items()},
        "smoke": float(args.smoke)}}

    if not args.skip_compare:
        scenarios = [s for s in str(args.scenarios).split(",") if s]
        if args.smoke:
            scenarios = scenarios[:1]
        report["compare"] = run_compare(scenarios, smoke=args.smoke,
                                        seed=args.seed)

    write_bench_json(report, out=args.out)

    # ---- human report (stderr): control-plane cost vs modeled stall ----
    print(f"\ncontrol plane (measured wall-clock, this machine — "
          f"reported separately from modeled stall):", file=sys.stderr)
    for k in ("digest", "routing", "tracking", "admission",
              "stall_pricing", "metrics"):
        print(f"  {k:>13s}: {timings[k]*1e3:9.1f} ms", file=sys.stderr)
    print(f"  {'throughput':>13s}: {timings['keys_per_sec']/1e6:9.2f} "
          f"M keys/s steady-state", file=sys.stderr)
    if obs is not None:
        print(f"  metrics plane on: "
              f"accesses={obs.metrics.counter('scale_accesses').value():.0f}"
              f" ledger flash_service="
              f"{obs.ledger.totals['flash_service']:.3f}s",
              file=sys.stderr)
    print(f"\nmodeled (deterministic, in the JSON): "
          f"hit_rate={record['hit_rate']:.3f} "
          f"per_access_stall={record['per_access_stall']*1e6:.1f}us "
          f"owner_imbalance={record['owner_imbalance']:.3f}",
          file=sys.stderr)

    if "compare" in report:
        print(f"\n{'scenario':>10s} {'arm':>11s} {'tok/s':>8s} "
              f"{'stall us/tok':>13s} {'idle slot-steps':>15s} "
              f"{'ticks':>6s}", file=sys.stderr)
        all_win = True
        for scen, cell in report["compare"].items():
            if scen == "splice_traces":
                continue
            for arm in ("continuous", "lockstep"):
                r = cell[arm]
                print(f"{scen:>10s} {arm:>11s} {r['tokens_per_sec']:8.1f} "
                      f"{r['per_token_stall']*1e6:13.1f} "
                      f"{r['slot_idle_steps']:15d} {r['ticks']:6d}",
                      file=sys.stderr)
            print(f"{'':>10s} identical_tokens={cell['tokens_identical']} "
                  f"throughput x{cell['throughput_ratio']:.3f} "
                  f"stall x{cell['stall_ratio']:.3f} "
                  f"wins={cell['continuous_wins']}", file=sys.stderr)
            all_win = all_win and cell["continuous_wins"] \
                and cell["tokens_identical"]
        print(f"\ncontinuous >= lockstep everywhere: {all_win}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
