"""Render the dry-run/roofline grid (results/dryrun/*.json) as tables for
EXPERIMENTS.md and pick hillclimb candidates."""
from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

ARCH_ORDER = [
    "qwen3-moe-235b-a22b", "llama4-maverick-400b-a17b", "xlstm-350m",
    "deepseek-7b", "granite-20b", "gemma-2b", "mistral-nemo-12b",
    "whisper-medium", "qwen2-vl-2b", "zamba2-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh="single"):
    out = {}
    for p in RESULTS.glob(f"*__{mesh}.json"):
        d = json.loads(p.read_text())
        _rederive(d)
        out[(d["arch"], d["shape"])] = d
    return out


def _rederive(d):
    """Recompute the roofline dict from stored probe terms (robust to
    formula changes after a cached run; uses chunked-path bytes when
    available)."""
    if "total" not in d or "model_flops" not in d:
        return
    import sys as _s, pathlib as _p
    _s.path.insert(0, str(_p.Path(__file__).resolve().parents[1] / "src"))
    from repro.launch.roofline import CostTerms, roofline
    t = d["total"]
    total = CostTerms(t["flops"], t["bytes_accessed"], t["wire_bytes"],
                      t["wire_by_kind"])
    if "total_chunked" in d:
        # FLOPs from the exact (unchunked) probes — scan-free, fully
        # counted; bytes AND collectives from the chunked probes — the
        # path the production artifact actually runs (the exact path can
        # trigger SPMD replicate-reshard fallbacks it never executes).
        c = d["total_chunked"]
        total = CostTerms(total.flops, c["bytes_accessed"],
                          c["wire_bytes"], c["wire_by_kind"])
    d["roofline"] = roofline(total, d["chips"], d["model_flops"])


def fmt_t(x):
    if x >= 1.0:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.1f}us"


def single_pod_table(res):
    rows = []
    hdr = (f"| {'arch':27s} | {'shape':11s} | {'peak GiB':>8s} | fit | "
           f"{'t_comp':>9s} | {'t_mem':>9s} | {'t_coll':>9s} | "
           f"{'dominant':10s} | {'useful':>6s} | {'roofline':>8s} |")
    rows.append(hdr)
    rows.append("|" + "-" * (len(hdr) - 2) + "|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = res.get((arch, shape))
            if d is None:
                rows.append(f"| {arch:27s} | {shape:11s} | "
                            f"{'—':>8s} |  —  | {'(skipped: quadratic-attention arch)':>45s} |")
                continue
            r = d.get("roofline")
            m = d["memory"]
            if r is None:
                continue
            rows.append(
                f"| {arch:27s} | {shape:11s} | {m['peak_gib']:8.2f} | "
                f"{'yes' if m['fits'] else 'NO '} | "
                f"{fmt_t(r['t_compute'])} | {fmt_t(r['t_memory'])} | "
                f"{fmt_t(r['t_collective'])} | {r['dominant']:10s} | "
                f"{r['useful_flop_ratio']:6.3f} | "
                f"{r['roofline_fraction']:8.3f} |")
    return "\n".join(rows)


def multi_pod_table(res_multi):
    rows = []
    hdr = (f"| {'arch':27s} | {'shape':11s} | {'peak GiB':>8s} | fit | "
           f"{'compile':>7s} | {'wire GiB/dev':>12s} |")
    rows.append(hdr)
    rows.append("|" + "-" * (len(hdr) - 2) + "|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = res_multi.get((arch, shape))
            if d is None:
                continue
            m = d["memory"]
            wire = d["scanned_artifact"]["wire_bytes"] / 2**30
            rows.append(
                f"| {arch:27s} | {shape:11s} | {m['peak_gib']:8.2f} | "
                f"{'yes' if m['fits'] else 'NO '} | "
                f"{d['compile_s']:6.1f}s | {wire:12.3f} |")
    return "\n".join(rows)


def candidates(res):
    """Hillclimb picks: worst roofline fraction (train cells), most
    collective-bound, paper-representative MoE."""
    scored = [(k, d["roofline"]) for k, d in res.items()
              if "roofline" in d]
    train = [(k, r) for k, r in scored if k[1] == "train_4k"]
    worst = min(train, key=lambda kr: kr[1]["roofline_fraction"])
    coll = max(scored, key=lambda kr: kr[1]["t_collective"]
               / max(kr[1]["step_time_bound"], 1e-12))
    moe = [(k, r) for k, r in scored
           if k[0].startswith(("qwen3", "llama4")) and k[1] == "train_4k"]
    rep = max(moe, key=lambda kr: kr[1]["t_collective"])
    return {"worst_fraction": worst[0], "most_collective": coll[0],
            "paper_representative": rep[0]}


def decode_throughput_table(res):
    """Serving view: per-pod decode tokens/s bound = batch / step bound."""
    rows = []
    batches = {"decode_32k": 128, "long_500k": 1}
    for arch in ARCH_ORDER:
        for shape, B in batches.items():
            d = res.get((arch, shape))
            if d is None or "roofline" not in d:
                continue
            r = d["roofline"]
            t = r["step_time_bound"]
            rows.append(f"| {arch:27s} | {shape:10s} | "
                        f"{fmt_t(t)} | {B / t:12.0f} | "
                        f"{r['dominant']:10s} |")
    hdr = (f"| {'arch':27s} | {'shape':10s} | {'t_bound':>9s} | "
           f"{'tokens/s/pod':>12s} | {'bound by':10s} |")
    return hdr + "\n" + "\n".join(rows)


def variant_table():
    """Hillclimb-variant cells (tagged __tokens / __mbN) vs their
    baselines."""
    rows = []
    for p in sorted(RESULTS.glob("*__single__*.json")):
        d = json.loads(p.read_text())
        _rederive(d)
        tag = p.stem.split("__single__")[1]
        base_p = RESULTS / f"{d['arch']}__{d['shape']}__single.json"
        if not base_p.exists() or "roofline" not in d:
            continue
        b = json.loads(base_p.read_text())
        _rederive(b)
        rb, rv = b["roofline"], d["roofline"]
        rows.append(
            f"| {d['arch']:27s} | {d['shape']:11s} | {tag:8s} | "
            f"bound {fmt_t(rb['step_time_bound'])} -> "
            f"{fmt_t(rv['step_time_bound'])} "
            f"({rb['step_time_bound']/max(rv['step_time_bound'],1e-12):5.1f}x) | "
            f"peak {b['memory']['peak_gib']:6.1f} -> "
            f"{d['memory']['peak_gib']:6.1f} GiB |")
    return "\n".join(rows)


def main():
    res = load("single")
    print("## Single-pod (16x16 = 256 chips) roofline grid\n")
    print(single_pod_table(res))
    multi = load("multi")
    if multi:
        print("\n## Multi-pod (2x16x16 = 512 chips) dry-run\n")
        print(multi_pod_table(multi))
    print("\n## Decode throughput bounds (serving view)\n")
    print(decode_throughput_table(res))
    vt = variant_table()
    if vt:
        print("\n## Hillclimb variants (vs baseline)\n")
        print(vt)
    print("\n## Hillclimb candidates\n")
    for k, v in candidates(res).items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
