"""Tenant-isolation benchmark: a declared scenario pack vs its SLOs.

Replays one `WorkloadDecl` pack — a premium chat tenant with a declared
p99 stall budget and `alpha_stall` rent, a batch tenant, and a
scan-flood adversary — through three arms of the same platform:

  * ``gated``        — `isolation="per-tenant"`: every tenant gets its
    own tau_be (SLO `alpha_stall` folded in) and its declared think-gap
    prior; the flood is priced straight to flash.
  * ``shared``       — the control: one fleet-wide threshold and class
    (the pre-WorkloadDecl behavior). The shared prior that welcomes
    premium's gaps welcomes the flood too; capacity pressure then
    demotes paused premium KV and its resumes pay the flash queue.
  * ``no_adversary`` — the shared gate without the scan tenant, showing
    the violation is the adversary's doing, not the shared gate's.

Acceptance (asserted by tests, reported here): premium's p99 per-token
restore stall meets its declared budget in ``gated`` and
``no_adversary``, and violates it in ``shared``.

The JSON is deterministic (virtual clock, seeded draws, greedy decode):
CI runs `--smoke` twice and diffs the bytes.

  PYTHONPATH=src python benchmarks/serving_tenants.py --smoke
  PYTHONPATH=src python benchmarks/serving_tenants.py \
      --scan-sessions 16 --dram-blobs 8 --out tenants.json
"""
import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--premium-sessions", type=int, default=4)
    ap.add_argument("--batch-sessions", type=int, default=3)
    ap.add_argument("--scan-sessions", type=int, default=10,
                    help="adversary flood size (paused blobs)")
    ap.add_argument("--dram-blobs", type=int, default=8,
                    help="host DRAM capacity in KV-blob units")
    ap.add_argument("--budget", type=float, default=2e-6,
                    help="premium p99 per-token stall budget (s/token)")
    ap.add_argument("--horizon", type=int, default=96)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="pinned small pack for the CI determinism gate")
    ap.add_argument("--trace", action="store_true",
                    help="compile the arms with the causal tracer on "
                         "and export a Perfetto trace per arm")
    ap.add_argument("--trace-out", type=pathlib.Path, default=None,
                    help="trace export prefix (default tenants_trace; "
                         "writes <prefix>_<arm>.json)")
    ap.add_argument("--out", type=pathlib.Path, default=None)
    args = ap.parse_args()

    from repro.obs import write_bench_json
    from repro.serving.tenants import run_tenant_bench, tenant_pack

    if args.smoke:
        spec = tenant_pack()            # the pinned default pack
    else:
        spec = tenant_pack(premium_sessions=args.premium_sessions,
                           batch_sessions=args.batch_sessions,
                           scan_sessions=args.scan_sessions,
                           dram_blobs=args.dram_blobs,
                           p99_stall_budget=args.budget,
                           horizon_steps=args.horizon, seed=args.seed)
    trace_sink = None
    if args.trace:
        from repro.platform import ObservabilityDecl
        spec = dataclasses.replace(
            spec, observability=ObservabilityDecl(trace=True))
        trace_sink = {}
    report = run_tenant_bench(spec, max_slots=args.max_slots,
                              trace_sink=trace_sink)

    write_bench_json(report, out=args.out)

    if trace_sink:
        prefix = args.trace_out or pathlib.Path("tenants_trace")
        for arm, tracer in sorted(trace_sink.items()):
            p = prefix.with_name(f"{prefix.name}_{arm}.json")
            p.write_text(tracer.to_chrome_json() + "\n")
            print(f"perfetto trace ({arm}): {p} ({len(tracer)} events)",
                  file=sys.stderr)

    # ---- human report (stderr) ----------------------------------------
    print(f"\n{'arm':>13s} {'tenant':>8s} {'sessions':>8s} {'tokens':>7s} "
          f"{'p99 stall us/tok':>17s} {'resumes':>8s} {'misses':>7s}",
          file=sys.stderr)
    for arm in ("gated", "shared", "no_adversary"):
        cell = report[arm]["report"].get("tenants", {})
        for tenant, d in cell.items():
            print(f"{arm:>13s} {tenant:>8s} {d['sessions']:8d} "
                  f"{d['tokens']:7d} {d['p99_per_token_stall']*1e6:17.3f} "
                  f"{d['resumes']:8d} {d['deadline_misses']:7d}",
                  file=sys.stderr)
        taus = report[arm]["tau_be"]
        print(f"{'':>13s} tau_be: " + "  ".join(
            f"{k}={v:.2f}s" for k, v in sorted(taus.items())),
            file=sys.stderr)
    for tenant, v in report["verdicts"].items():
        print(f"\n{tenant}: budget={v['budget']*1e6:.2f}us/tok  "
              f"gated={v['gated_p99']*1e6:.3f} "
              f"shared={v['shared_p99']*1e6:.3f} "
              f"no_adversary={v['no_adversary_p99']*1e6:.3f}  "
              f"gated_ok={v['gated_meets_budget']} "
              f"shared_violates={v['shared_violates']} "
              f"causal={v['adversary_causal']}", file=sys.stderr)
    print(f"\nisolation effective: {report['isolation_effective']}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
